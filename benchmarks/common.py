"""Shared benchmark utilities: the paper's experimental protocol."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import simulate
from repro.core.simulate import rounds_to_target


@dataclass
class AlgoResult:
    algo: str
    rounds: Optional[int]     # comm rounds to target (None = not reached)
    final_gap: float
    iters: int
    wall_s: float
    history: list
    comm_bytes: int = 0       # modeled bytes (repro.comm) over all rounds run
    comm_time_s: float = 0.0  # α–β modeled comm wall-clock


def run_algo(algo: str, loss_fn, p0, data, eval_fn, fstar: float, *,
             target_gap: float, eta1: float, T1: int, k1: float,
             n_stages: int, iid: bool, batch: int, max_rounds: int,
             lr_alpha: float = 0.0, gamma_inv: float = 0.0,
             momentum: float = 0.0, batch_growth: float = 1.05,
             max_batch: int = 256, seed: int = 0,
             eval_every: int = 8, reducer: str = "dense") -> AlgoResult:
    cfg = TrainConfig(algo=algo, eta1=eta1, T1=T1, k1=k1, n_stages=n_stages,
                      iid=iid, batch_per_client=batch, gamma_inv=gamma_inv,
                      momentum=momentum, batch_growth=batch_growth,
                      max_batch=max_batch, seed=seed, reducer=reducer)
    t0 = time.time()
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn,
                        eval_every=eval_every, max_rounds=max_rounds,
                        target=fstar + target_gap, lr_alpha=lr_alpha)
    wall = time.time() - t0
    from repro.comm import comm_summary_for

    n_clients = jax.tree.leaves(data)[0].shape[0]
    summ = comm_summary_for(cfg, p0, n_clients, hist[-1].round)
    return AlgoResult(algo, rounds_to_target(hist, fstar + target_gap),
                      hist[-1].value - fstar, hist[-1].iteration, wall,
                      [(h.round, h.value) for h in hist],
                      comm_bytes=summ["total_bytes"],
                      comm_time_s=summ["total_time_s"])


def parse_reducers(argv) -> tuple:
    """Parse a ``--reducer dense,int8,topk`` sweep axis from a CLI argv."""
    value = None
    for i, a in enumerate(argv):
        if a == "--reducer":
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                raise SystemExit("--reducer needs a value, e.g. "
                                 "--reducer dense,int8,topk")
            value = argv[i + 1]
        elif a.startswith("--reducer="):
            value = a.split("=", 1)[1]
    if value is None:
        return ("dense",)
    reducers = tuple(r for r in value.split(",") if r)
    if not reducers:
        raise SystemExit("--reducer needs a value, e.g. "
                         "--reducer dense,int8,topk")
    return reducers


def find_fstar(eval_fn, p0, lr: float = 1.0, iters: int = 4000) -> float:
    """Near-exact optimum by full-batch GD (convex problems)."""
    p = p0
    step = jax.jit(lambda p: jax.tree.map(
        lambda a, g: a - lr * g, p, jax.grad(eval_fn)(p)))
    for _ in range(iters):
        p = step(p)
    return float(eval_fn(p))


def print_table(title: str, rows: List[Dict], cols: List[str]):
    print(f"\n## {title}")
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))


def save_artifact(name: str, payload, directory: str = "artifacts/convergence"):
    import json
    import os

    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def save_bench(name: str, rows, meta: Optional[Dict] = None,
               directory: Optional[str] = None):
    """Write a BENCH_<name>.json perf-trajectory artifact.

    Schema v1: {"bench", "schema", "meta", "rows"} where each row carries the
    bench's own columns plus (when the run models communication) the
    repro.comm fields ``comm_bytes`` and ``comm_time_s``. benchmarks/report.py
    renders these into the comm-cost table, and ``repro.obs.diff`` /
    tools/bench_diff.py compare them against committed baselines.

    Output directory: explicit ``directory`` arg > ``REPRO_BENCH_DIR`` env
    var > ``artifacts/bench`` — the env var is how a baseline-refresh run
    writes straight into ``benchmarks/results/<scale>/``.
    """
    import json
    import os

    if directory is None:
        directory = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "schema": 1, "meta": meta or {},
                   "rows": rows}, f, indent=1, default=str)
    return path
