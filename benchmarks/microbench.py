"""Micro-benchmarks: wall-time of the framework's primitive operations on
this host (CPU) — smoke-scale numbers proving the pipelines execute, in the
required ``name,us_per_call,derived`` format."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import local_sgd as LS
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as TF


def _time(fn, *args, n=5):
    fn(*args)  # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6  # us


def run(quick: bool = True):
    rows = []
    mesh = make_host_mesh(1, 1)
    cfg = get_arch("qwen3-14b", smoke=True)
    C, B, S = 2, 2, 128
    state = LS.init_state(jax.random.key(0), cfg, C)
    batch = {
        "tokens": jnp.zeros((C, B, S), jnp.int32),
        "labels": jnp.zeros((C, B, S), jnp.int32),
    }
    local_step, sync_step, _ = LS.build_train_steps(cfg, mesh)
    jl, js = jax.jit(local_step), jax.jit(sync_step)
    us = _time(lambda: jl(state, batch, 0.01)[0]["params"])
    tokens = C * B * S
    rows.append(("train_local_step_smoke", us, f"{tokens / us:.2f}Mtok/s" if False else f"{tokens/(us/1e6):.0f}tok/s"))
    us = _time(lambda: js(state)["params"])
    rows.append(("sync_round_smoke", us, "param_avg"))

    params = TF.init_params(jax.random.key(0), cfg)
    cache = TF.init_cache(cfg, B, 256)
    tok = jnp.zeros((B, 1), jnp.int32)
    jd = jax.jit(lambda p, t, c: TF.decode_step(p, cfg, t, c))
    us = _time(lambda: jd(params, tok, cache)[0])
    rows.append(("decode_step_smoke", us, f"{B/(us/1e6):.0f}tok/s"))

    from repro.kernels.flash_attention.ops import flash_attention
    q = jnp.ones((1, 256, 4, 64), jnp.float32)
    k = jnp.ones((1, 256, 2, 64), jnp.float32)
    jf = jax.jit(lambda q, k: flash_attention(q, k, k, impl="xla"))
    us = _time(lambda: jf(q, k))
    rows.append(("flash_attention_xla_256", us, "oracle"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
