"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > artifacts/report.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import analyse_cell


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(art_dir="artifacts/dryrun", pattern="*.json"):
    lines = ["| arch | shape | mesh | program | peak B/dev | HLO flops/dev† | "
             "coll link-bytes (loop-wtd) | client-axis bytes | model-axis bytes |",
             "|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(art_dir, pattern))):
        rec = json.load(open(path))
        mesh = "x".join(str(v) for v in rec["mesh"].values())
        tag = " (hier)" if rec.get("hierarchical") else ""
        for p in rec["programs"]:
            ba = p["collectives"]["by_axes"]
            client_b = sum(v for k, v in ba.items() if "data" in k or "pod" in k)
            model_b = sum(v for k, v in ba.items() if "model" in k)
            lines.append(
                f"| {rec['arch']}{tag} | {rec['shape']} | {mesh} | {p['program']} "
                f"| {_fmt_bytes(p['memory'].get('peak_bytes'))} "
                f"| {p['cost'].get('flops', 0):.2e} "
                f"| {_fmt_bytes(p['collectives']['total_link_bytes'])} "
                f"| {_fmt_bytes(client_b)} | {_fmt_bytes(model_b)} |")
    return "\n".join(lines)


def comm_table(art_dir="artifacts/bench", pattern="BENCH_*.json"):
    """Render the comm-cost columns of the BENCH_*.json perf trajectory.

    Every convergence bench writes a BENCH artifact whose rows carry
    ``comm_bytes`` / ``comm_time_s`` (modeled by repro.comm's α–β network
    cost model) alongside the round counts, so the perf trajectory tracks
    communication cost, not just round counts.
    """
    lines = ["| bench | cell | reducer | rounds | comm bytes | comm time |",
             "|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(art_dir, pattern))):
        rec = json.load(open(path))
        for r in rec.get("rows", []):
            if "comm_bytes" not in r:
                continue
            cell = " ".join(str(r[k]) for k in ("dataset", "net", "dist",
                                                "algo") if k in r)
            # pre-PR-1 artifacts (and clock-only benches) may carry bytes
            # without modeled seconds — render what is there
            ct = r.get("comm_time_s")
            ct_s = "-" if ct is None else f"{float(ct):.2f}s"
            lines.append(
                f"| {rec['bench']} | {cell} | {r.get('reducer', 'dense')} "
                f"| {r.get('rounds', '-')} | {_fmt_bytes(r['comm_bytes'])} "
                f"| {ct_s} |")
    return "\n".join(lines)


def reducer_sweep_table(art_dir="artifacts/bench", pattern="BENCH_*.json"):
    """Compose the rounds × bytes × modeled-time reducer sweep.

    Pivots every BENCH artifact's rows over their ``reducer`` column: cells
    that share all other identity columns (bench, algo, dataset, …) are one
    sweep group, the dense run is its baseline, and each compressed reducer
    reports its bytes/time ratios and final-objective drift against it —
    the reporting half of the ROADMAP's "paper-scale reducer sweeps".
    """
    _ID_KEYS = ("dataset", "net", "dist", "algo", "mode", "slowdown")
    _OBJ_KEYS = ("final_obj", "final_gap", "final_err", "gap")
    groups = {}
    for path in sorted(glob.glob(os.path.join(art_dir, pattern))):
        rec = json.load(open(path))
        for r in rec.get("rows", []):
            if "comm_bytes" not in r or "reducer" not in r:
                continue
            cell = tuple((k, str(r[k])) for k in _ID_KEYS if k in r)
            groups.setdefault((rec["bench"], cell), {})[r["reducer"]] = r

    def _obj(r):
        for k in _OBJ_KEYS:
            if k in r:
                return float(r[k])
        return None

    lines = ["| bench | cell | reducer | rounds | bytes | ×dense bytes | "
             "time | ×dense time | obj drift |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (bench, cell), by_red in sorted(groups.items()):
        base = by_red.get("dense")
        if base is None or len(by_red) < 2:
            continue
        cell_s = " ".join(v for _, v in cell)
        for red, r in by_red.items():
            bx = float(base["comm_bytes"]) / max(float(r["comm_bytes"]), 1.0)
            # comm_time_s is optional on either side (older artifacts):
            # bytes ratios always render, time columns degrade to "-"
            bt, rt = base.get("comm_time_s"), r.get("comm_time_s")
            t_s = "-" if rt is None else f"{float(rt):.2f}s"
            tx_s = ("-" if bt is None or rt is None
                    else f"{float(bt) / max(float(rt), 1e-12):.1f}x")
            o, ob = _obj(r), _obj(base)
            drift = ("-" if o is None or ob is None or ob == 0.0
                     else f"{abs(o - ob) / abs(ob) * 100:.2f}%")
            lines.append(
                f"| {bench} | {cell_s} | {red} | {r.get('rounds', '-')} "
                f"| {_fmt_bytes(float(r['comm_bytes']))} | {bx:.1f}x "
                f"| {t_s} | {tx_s} | {drift} |")
    return "\n".join(lines)


def bench_diff_table(baseline_dir="benchmarks/results/smoke",
                     current_dir="artifacts/bench", tol=0.05):
    """Regression view: a fresh run's BENCH artifacts vs committed baselines.

    Uses ``repro.obs.diff`` — rows match by identity columns, monitored
    numeric columns (modeled bytes/seconds, rounds, modeled wall-clock)
    compare at relative tolerance ``tol``; scale-mismatched artifacts are
    skipped. Rendering only — ``tools/bench_diff.py`` is what CI gates on.
    """
    from repro.obs.diff import diff_dirs

    dd = diff_dirs(baseline_dir, current_dir)
    lines = [f"compared: {', '.join(dd.compared) or '(none)'}"]
    for s in dd.skipped:
        lines.append(f"skipped: {s}")
    regs = dd.regressions(tol)
    imps = dd.improvements(tol)
    lines.append(f"\n{len(regs)} regression(s), {len(imps)} improvement(s) "
                 f"at tol={tol:.0%}:")
    for d in regs:
        lines.append(f"  REGRESSED  {d.render()}")
    for d in imps:
        lines.append(f"  improved   {d.render()}")
    return "\n".join(lines)


def roofline_table(art_dir="artifacts/dryrun", pattern="*singlepod.json"):
    lines = ["| arch | shape | program | compute s | memory s | collective s | "
             "dominant | MODEL_FLOPS | useful ratio | fits 16G | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(art_dir, pattern))):
        row = analyse_cell(path)
        if not row:
            continue
        lever = _lever(row)
        lines.append(
            f"| {row['arch']} | {row['shape']} | {row['program']} "
            f"| {row['t_compute_s']} | {row['t_memory_s']} "
            f"| {row['t_collective_s']} | **{row['dominant']}** "
            f"| {row['model_flops']} | {row['useful_ratio']} "
            f"| {row['fits_16g']} | {lever} |")
    return "\n".join(lines)


def _lever(row) -> str:
    dom = row["dominant"]
    if dom == "memory":
        if "decode" in row["shape"] or "500k" in row["shape"]:
            return "int8/latent KV cache; batch KV reads"
        return "smaller remat live set; fused update"
    if dom == "compute":
        if float(row["useful_ratio"]) < 0.6:
            return "cut remat recompute; tighter attention banding"
        return "near roofline — overlap collectives"
    if row["program"] in ("prefill_step", "serve_step"):
        return "grouped/shard_map MoE dispatch; narrower TP"
    return "raise k_s (paper); narrower TP; overlap sync"


def main():
    print("### Dry-run matrix (all programs, all meshes)\n")
    print(dryrun_table())
    print("\n\n### Roofline — single-pod (16×16)\n")
    print(roofline_table(pattern="*singlepod.json"))
    print("\n\n### Roofline — multi-pod (2×16×16)\n")
    print(roofline_table(pattern="*multipod.json"))
    print("\n\n### Communication cost (α–β model, BENCH trajectory)\n")
    print(comm_table())
    print("\n\n### Reducer sweep — rounds × bytes × modeled time vs dense\n")
    print(reducer_sweep_table())
    if os.path.isdir("benchmarks/results/smoke"):
        print("\n\n### Bench diff — fresh artifacts vs committed baselines\n")
        print(bench_diff_table())


if __name__ == "__main__":
    main()
