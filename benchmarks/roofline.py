"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell:
    compute term    = FLOPs / (chips × peak)        [analytical model —
                      HLO undercounts scan bodies; reported as cross-check]
    memory term     = HBM bytes / (chips × HBM bw)
    collective term = link bytes / link bw           [loop-weighted HLO parse]
plus the dominant term, MODEL_FLOPS = 6·N_active·D, the useful-compute ratio,
and — for train cells — the STL-SGD amortized communication at stage s
(sync bytes / k_s) vs the SyncSGD per-step gradient all-reduce.

Collective terms are priced with the calibrated α–β link models from
``repro.comm.link_model`` (bandwidths tied to the ICI_BW/DCN_BW constants
in launch/mesh.py, so the per-hop latency term shows up in the tables
instead of a bare bytes/bandwidth ratio).
"""
from __future__ import annotations

import glob
import json
import math
import os
from typing import Optional

from repro.comm import link_model
from repro.configs import SHAPES, arch_for_shape
from repro.launch.flops import shape_flops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def analyse_cell(path: str) -> Optional[dict]:
    with open(path) as f:
        rec = json.load(f)
    mesh = rec["mesh"]
    chips = 1
    for v in mesh.values():
        chips *= v
    shape = SHAPES[rec["shape"]]
    cfg = arch_for_shape(rec["arch"], rec["shape"])
    fr = shape_flops(cfg, shape)

    programs = {p["program"]: p for p in rec["programs"]}
    main = programs.get("local_step") or programs.get("serve_step") \
        or programs.get("prefill_step")
    if main is None:
        return None

    t_compute = fr.step_flops / (chips * PEAK_FLOPS_BF16)
    # memory: use HLO bytes when plausible (per device) else analytical
    hlo_bytes = main["cost"].get("bytes_accessed") or 0.0
    t_memory_hlo = hlo_bytes / HBM_BW  # per device already
    t_memory_model = fr.hbm_bytes / (chips * HBM_BW)
    t_memory = max(t_memory_hlo, t_memory_model)

    coll = main["collectives"]
    by_axes = coll.get("by_axes", {})
    # HLO shapes are per-device after SPMD partitioning, so parsed collective
    # bytes are already per-device link traffic — no division by chip count.
    # α–β per hop: inter-pod traffic crosses the DCN, the rest stays on ICI.
    ici_net, dcn_net = link_model("ici"), link_model("dcn")
    t_coll = 0.0
    for axes, b in by_axes.items():
        t_coll += (dcn_net if "pod" in axes else ici_net).time(b)

    hlo_flops = main["cost"].get("flops") or 0.0
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    out = {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(str(v) for v in mesh.values()),
        "variant": rec.get("arch_variant", ""),
        "program": main["program"],
        "t_compute_s": f"{t_compute:.3e}",
        "t_memory_s": f"{t_memory:.3e}",
        "t_collective_s": f"{t_coll:.3e}",
        "dominant": dominant,
        "model_flops": f"{fr.model_flops:.3e}",
        "step_flops_analytical": f"{fr.step_flops:.3e}",
        "useful_ratio": f"{fr.model_flops / fr.step_flops:.2f}",
        "hlo_flops_per_dev(loop-body-once)": f"{hlo_flops:.3e}",
        "peak_bytes_dev": main["memory"].get("peak_bytes"),
        "fits_16g": "Y" if (main["memory"].get("peak_bytes") or 0) < 16e9 else "N",
    }

    # STL-SGD vs SyncSGD communication story (train cells): amortized α–β
    # comm time per local step — the sync round's (latency + serialization)
    # is paid once every k steps, so both α and β amortize with k_s.
    if "sync_step" in programs and "syncsgd_step" in programs:
        sync_b = programs["sync_step"]["collectives"]["total_link_bytes"]
        ssgd = programs["syncsgd_step"]["collectives"]["by_axes"]
        ssgd_client = sum(b for a, b in ssgd.items()
                          if "data" in a or "pod" in a)
        local_client = sum(b for a, b in by_axes.items()
                           if ("data" in a or "pod" in a))
        out["syncsgd_client_bytes_per_step"] = f"{ssgd_client:.3e}"
        out["stl_sync_bytes_per_round"] = f"{sync_b:.3e}"
        for k in (1, 8, 64):
            amort = local_client / ici_net.bandwidth_Bps \
                + ici_net.time(sync_b) / k
            out[f"stl_comm_s_k{k}"] = f"{amort:.3e}"
        out["syncsgd_comm_s"] = f"{ici_net.time(ssgd_client):.3e}"
    return out


def run(art_dir: str = "artifacts/dryrun", pattern: str = "*singlepod.json"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, pattern))):
        try:
            row = analyse_cell(path)
            if row:
                rows.append(row)
        except Exception as e:
            rows.append({"arch": os.path.basename(path), "dominant": f"ERR {e}"})
    cols = ["arch", "shape", "mesh", "program", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "useful_ratio", "fits_16g"]
    from benchmarks.common import print_table

    print_table("Roofline (per arch × shape × mesh)", rows, cols)
    return rows


if __name__ == "__main__":
    import sys

    run(pattern=sys.argv[1] if len(sys.argv) > 1 else "*singlepod.json")
