# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV for the micro-benches, then the paper-table reproductions and the
# roofline analysis derived from the dry-run artifacts.
#
#   PYTHONPATH=src python -m benchmarks.run [--full] [--skip-convergence]
#                                           [--diff [BASELINE_DIR]]
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (hours); default quick mode")
    ap.add_argument("--skip-convergence", action="store_true",
                    help="only micro-benches + complexity + roofline")
    ap.add_argument("--diff", nargs="?", const="benchmarks/results/smoke",
                    default=None, metavar="BASELINE_DIR",
                    help="after the sweeps, diff the fresh BENCH_*.json "
                         "artifacts against this baseline directory "
                         "(repro.obs.diff; exits nonzero on a >5%% "
                         "regression in any monitored modeled column)")
    args = ap.parse_args()
    quick = not args.full
    t0 = time.time()

    print("name,us_per_call,derived")
    from benchmarks import microbench

    microbench.run(quick=quick)

    from benchmarks import table3_complexity

    table3_complexity.run(quick=quick)

    from benchmarks import roofline

    try:
        roofline.run()
    except Exception as e:  # artifacts may not exist yet
        print(f"[roofline] skipped: {e}", file=sys.stderr)

    if not args.skip_convergence:
        from benchmarks import table1_convex, table2_nonconvex, table4_comm_cost

        table1_convex.run(quick=quick)
        table2_nonconvex.run(quick=quick)
        table4_comm_cost.run(quick=quick)

    print(f"\n[benchmarks] done in {time.time() - t0:.0f}s")

    if args.diff:
        from tools.bench_diff import main as bench_diff_main

        rc = bench_diff_main([args.diff, "artifacts/bench"])
        if rc:
            raise SystemExit(rc)


if __name__ == "__main__":
    main()
