"""Paper Table 1 / Figure 1: communication rounds to reach the target
objective gap for L2-regularized logistic regression, IID and Non-IID,
N=32 clients — SyncSGD / LB-SGD / CR-PSGD / Local SGD / STL-SGD^sc.

Datasets are synthetic stand-ins with a9a/MNIST-like dimensions (offline
container), the protocol (partitioner s=50%, λ=1/n, tuned η/k/B per
algorithm) follows §5.1. The claim under test: STL-SGD^sc needs the fewest
rounds, with the ordering SyncSGD ≫ LB/CR-PSGD ≫ Local SGD > STL-SGD^sc.

``--reducer`` adds a compressed-round axis (table4's sweep pattern at paper
protocol scale): each named reducer reruns the full protocol and the rows
carry modeled comm_bytes/comm_time_s, so "fewer rounds" × "cheaper rounds"
lands in one table.

    PYTHONPATH=src python -m benchmarks.table1_convex [--full] \
        [--reducer dense,int8,topk]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import AlgoResult, find_fstar, print_table, run_algo
from repro.data import make_binary_classification, partition_iid, partition_paper
from repro.models import logreg


def make_problem(dataset: str, iid: bool, n_clients: int, quick: bool):
    if dataset == "a9a-like":
        n, d = (8192, 64) if quick else (32561, 123)
    else:  # mnist-binary-like
        n, d = (4096, 128) if quick else (11791, 784)
    x, y = make_binary_classification(n=n, d=d, seed=0)
    # paper: λ = 1/n. Quick mode uses 1e-3 (the paper's λ at its n≈32k gives a
    # condition number that needs ~100k rounds for SyncSGD — hours on 1 core).
    lam = 1e-3 if quick else 1.0 / n
    if iid:
        data = partition_iid(x, y, n_clients, seed=1)
    else:
        data = partition_paper(x, y, n_clients, iid_percent=50.0, seed=1)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    p0 = logreg.init_params(None, d)
    return loss_fn, eval_fn, p0, data


def run(quick: bool = True, reducers=("dense",)):
    n_clients = 8 if quick else 32
    target_gap = 1e-4
    max_rounds = 12000 if quick else 40000
    rows = []
    datasets = ["a9a-like"] if quick else ["a9a-like", "mnist-like"]
    for dataset in datasets:
        for iid in (True, False):
            loss_fn, eval_fn, p0, data = make_problem(dataset, iid, n_clients, quick)
            fstar = find_fstar(eval_fn, p0, lr=2.0, iters=4000 if quick else 8000)
            base = dict(loss_fn=loss_fn, p0=p0, data=data, eval_fn=eval_fn,
                        fstar=fstar, target_gap=target_gap, iid=iid,
                        batch=32, max_rounds=max_rounds, n_stages=14)
            T_budget = 1024 if quick else 4096
            k_loc = 16.0 if iid else 8.0
            runs = [
                ("sync", dict(eta1=0.5, T1=T_budget, k1=1.0, lr_alpha=1e-3,
                              n_stages=24)),
                ("lb", dict(eta1=0.5, T1=T_budget, k1=1.0, lr_alpha=1e-3,
                            n_stages=24)),
                ("crpsgd", dict(eta1=0.5, T1=T_budget, k1=1.0,
                                batch_growth=1.05, max_batch=256)),
                ("local", dict(eta1=0.5, T1=T_budget, k1=k_loc, lr_alpha=1e-3,
                               n_stages=24)),
                ("stl_sc", dict(eta1=0.5, T1=512, k1=k_loc, n_stages=11)),
            ]
            for reducer in reducers:
                sync_rounds = None
                for algo, kw in runs:
                    res = run_algo(algo, reducer=reducer, **{**base, **kw})
                    if algo == "sync":
                        sync_rounds = res.rounds
                    speed = (f"{sync_rounds / res.rounds:.1f}x"
                             if res.rounds and sync_rounds else "-")
                    rows.append({
                        "dataset": dataset, "dist": "IID" if iid else "Non-IID",
                        "algo": algo, "reducer": reducer, "rounds": res.rounds,
                        "speedup_vs_sync": speed,
                        "final_gap": f"{res.final_gap:.2e}",
                        "iters": res.iters, "wall_s": f"{res.wall_s:.0f}",
                        "comm_bytes": res.comm_bytes,
                        "comm_time_s": res.comm_time_s})
                    print(f"  {dataset} {'IID' if iid else 'NonIID'} {algo} "
                          f"[{reducer}]: rounds={res.rounds} "
                          f"gap={res.final_gap:.2e} ({res.wall_s:.0f}s)",
                          flush=True)
    print_table("Table 1 — convex (comm rounds to target gap)", rows,
                ["dataset", "dist", "algo", "reducer", "rounds",
                 "speedup_vs_sync", "final_gap", "iters", "wall_s",
                 "comm_bytes", "comm_time_s"])
    from benchmarks.common import save_artifact, save_bench

    save_artifact("table1_convex", rows)
    save_bench("table1_convex", rows,
               meta={"reducers": list(reducers),
                     "scale": "quick" if quick else "full"})
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import parse_reducers

    run(quick="--full" not in sys.argv, reducers=parse_reducers(sys.argv))
