"""Paper Table 2 / Figure 2: non-convex experiments — ResNet18/VGG16 topology
(width-reduced for CPU) on CIFAR-like synthetic images, 8 clients.

Communication rounds to reach the target train accuracy for SyncSGD / Local
SGD / STL-SGD^nc-1 / STL-SGD^nc-2. (LB-SGD/CR-PSGD omitted in quick mode —
the paper itself reports '-' for them on VGG16.) Claim under test: the
STL-SGD^nc variants reach the target in the fewest rounds, with ^nc-1
(geometric) ahead of ^nc-2 (linear).

``--reducer`` adds a compressed-round axis (table4's sweep pattern): each
named reducer reruns the protocol, rows carry modeled comm_bytes /
comm_time_s.

    PYTHONPATH=src python -m benchmarks.table2_nonconvex [--full] \
        [--reducer dense,int8,topk]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.configs.base import TrainConfig
from repro.core import simulate
from repro.data import make_multiclass_images
from repro.data.partition import partition_paper
from repro.models import cnn


def make_problem(net: str, quick: bool):
    n = 512 if quick else 8192
    x, y = make_multiclass_images(n=n, n_classes=10, seed=0, hw=16 if quick else 32)
    data_np = partition_paper(x, y, 8, iid_percent=0.0, seed=1)  # s=0 (paper)
    data = {"x": jnp.asarray(data_np["x"]), "y": jnp.asarray(data_np["y"])}
    width = 4 if quick else 16
    if net == "resnet18":
        params, strides = cnn.init_resnet18(jax.random.key(0), width=width)
        fwd = lambda p, xb: cnn.apply_resnet18(p, strides, xb)
    else:
        params = cnn.init_vgg16(jax.random.key(0), width=width)
        fwd = lambda p, xb: cnn.apply_vgg16(p, xb)

    def loss_fn(p, b):
        return cnn.cross_entropy(fwd(p, b["x"]), b["y"])

    xj, yj = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def err_fn(p):  # 1 - train accuracy (simulator minimises "value")
        pred = jnp.argmax(fwd(p, xj), axis=-1)
        return 1.0 - jnp.mean((pred == yj).astype(jnp.float32))

    return loss_fn, err_fn, params, data


def run(quick: bool = True, reducers=("dense",)):
    rows = []
    target_err = 0.02 if quick else 0.05
    max_rounds = 400 if quick else 4000
    nets = ["resnet18"] if quick else ["resnet18", "vgg16"]
    for net in nets:
        loss_fn, err_fn, p0, data = make_problem(net, quick)
        T1 = 48 if quick else 512
        runs = [
            ("sync", dict(algo="sync", eta1=0.005, T1=T1, k1=1.0, n_stages=30)),
            ("local", dict(algo="local", eta1=0.005, T1=T1, k1=8.0, n_stages=30)),
            ("stl_nc2", dict(algo="stl_nc2", eta1=0.005, T1=T1, k1=8.0,
                             n_stages=10, gamma_inv=0.01)),
            ("stl_nc1", dict(algo="stl_nc1", eta1=0.005, T1=T1, k1=8.0,
                             n_stages=8, gamma_inv=0.01)),
        ]
        for reducer in reducers:
            sync_rounds = None
            for name, kw in runs:
                cfg = TrainConfig(iid=False, batch_per_client=16, momentum=0.9,
                                  seed=0, reducer=reducer, **kw)
                t0 = time.time()
                hist = simulate.run(loss_fn, p0, data, cfg, err_fn, eval_every=4,
                                    max_rounds=max_rounds, target=target_err,
                                    chunk_rounds=8)
                wall = time.time() - t0
                reached = simulate.rounds_to_target(hist, target_err)
                if name == "sync":
                    sync_rounds = reached
                from repro.comm import comm_summary_for

                n_clients = jax.tree.leaves(data)[0].shape[0]
                summ = comm_summary_for(cfg, p0, n_clients, hist[-1].round)
                rows.append({
                    "net": net, "algo": name, "reducer": reducer,
                    "rounds": reached,
                    "speedup_vs_sync": (f"{sync_rounds / reached:.1f}x"
                                        if reached and sync_rounds else "-"),
                    "final_err": f"{hist[-1].value:.3f}",
                    "iters": hist[-1].iteration, "wall_s": f"{wall:.0f}",
                    "comm_bytes": summ["total_bytes"],
                    "comm_time_s": summ["total_time_s"]})
                print(f"  {net} {name} [{reducer}]: rounds={reached} "
                      f"err={hist[-1].value:.3f} ({wall:.0f}s)", flush=True)
    print_table("Table 2 — non-convex (comm rounds to target train acc)", rows,
                ["net", "algo", "reducer", "rounds", "speedup_vs_sync",
                 "final_err", "iters", "wall_s", "comm_bytes", "comm_time_s"])
    from benchmarks.common import save_artifact, save_bench

    save_artifact("table2_nonconvex", rows)
    save_bench("table2_nonconvex", rows,
               meta={"reducers": list(reducers),
                     "scale": "quick" if quick else "full"})
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import parse_reducers

    run(quick="--full" not in sys.argv, reducers=parse_reducers(sys.argv))
