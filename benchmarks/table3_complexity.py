"""Paper Table 3: communication-complexity orders.

Numerically validates the schedule implementations against the claimed
orders: we run each schedule symbolically (no training) over growing total
iteration budgets T and fit the scaling exponent of Σ T_s/k_s (and the log-T
linearity for the IID geometric case). This pins the *implementation* to the
*theorems* — the convergence benches pin it to practice.
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import print_table
from repro.core import schedules as S


def measured_rounds(algo: str, iid: bool, n_stages: int, N: int = 32,
                    eta1: float = 0.1, L: float = 1.0) -> tuple:
    k1 = max(S.theory_k1(eta1, L, N, iid=iid), 1.0)
    T1 = 256
    st = S.make_stages(algo, eta1, T1, k1, n_stages, iid)
    return S.total_iters(st), S.comm_rounds(st)


def fit_exponent(Ts, Rs):
    lt, lr = np.log(np.asarray(Ts, float)), np.log(np.asarray(Rs, float))
    return float(np.polyfit(lt, lr, 1)[0])


def run(quick: bool = True):
    rows = []
    cases = [
        # algo, iid, claimed T-exponent of comm complexity
        ("stl_sc", True, 0.0),    # O(N log T): sub-polynomial
        ("stl_sc", False, 0.5),   # O(√N √T)
        ("stl_nc1", True, 0.0),
        ("stl_nc1", False, 0.5),
        ("stl_nc2", True, 0.5),   # O(N^{3/2} T^{1/2})
        ("stl_nc2", False, 0.75), # O(N^{3/4} T^{3/4})
        ("local", True, 1.0),     # fixed k: rounds ∝ T
        ("sync", True, 1.0),      # rounds = T
    ]
    stage_range = range(6, 16, 3) if quick else range(6, 22, 2)
    for algo, iid, claimed in cases:
        Ts, Rs = [], []
        for n_stages in stage_range:
            T, R = measured_rounds(algo, iid, n_stages)
            Ts.append(T)
            Rs.append(R)
        exp = fit_exponent(Ts, Rs)
        # for the log-T cases the fitted exponent should drift to ~0 slowly;
        # accept < 0.25 as "sub-polynomial"
        ok = abs(exp - claimed) < 0.12 or (claimed == 0.0 and exp < 0.25)
        rows.append({"algo": algo, "dist": "IID" if iid else "Non-IID",
                     "claimed_T_exponent": claimed,
                     "fitted_exponent": f"{exp:.3f}",
                     "match": "OK" if ok else "MISMATCH"})
    print_table("Table 3 — communication-complexity orders", rows,
                ["algo", "dist", "claimed_T_exponent", "fitted_exponent",
                 "match"])
    assert all(r["match"] == "OK" for r in rows), rows
    return rows


if __name__ == "__main__":
    run(quick=False)
