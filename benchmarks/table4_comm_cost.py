"""Table 4 (beyond-paper): true communication cost of a round.

The paper only counts communication *rounds*; this table prices them. Each
cell runs one (algorithm × reducer) pair on the synthetic convex workload
and reports rounds, modeled bytes (repro.comm byte accounting for the
reducer's compressed representation), modeled wall-clock (α–β network
model) and the final objective — showing that stagewise periods (fewer
rounds) and compressed reducers (cheaper rounds) compose.

The claim under test: int8 / top-k reducers cut modeled bytes ≥ 3× while
landing within 5% of the dense final objective (error feedback absorbs the
compression bias).

A hierarchical pair of rows rides along (PR 5): the same stl_sc schedule
over 2 pods (dense intra-pod ICI + int8-EF inter-pod WAN), once through
the vmapped simulator and once through the ``StagewiseDriver`` — whose
sync step now emits the real two-level round — asserting the two
front-ends report identical rounds and bit-identical modeled bytes.

    PYTHONPATH=src python -m benchmarks.table4_comm_cost [--full]
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_artifact, save_bench
from repro.comm import NetworkModel, comm_summary_for
from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core import simulate
from repro.core.stl_sgd import StagewiseDriver, driver_state, \
    make_client_sgd_step
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg

# "adaptive" is the divergence-triggered SyncPolicy (engine.AdaptivePeriod):
# stl_sc's η_s/T_s schedule, rounds fired by the replica-divergence probe
ALGOS = ("sync", "local", "stl_sc", "stl_nc1", "adaptive")
REDUCERS = ("dense", "int8", "topk")

# acceptance thresholds (also asserted by tests/test_comm.py)
MIN_BYTES_RATIO = 3.0
MAX_OBJ_DRIFT = 0.05


def make_problem(quick: bool, n_clients: int):
    n, d = (2048, 64) if quick else (16384, 123)
    x, y = make_binary_classification(n=n, d=d, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, n_clients, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, logreg.init_params(None, d), data


def algo_cfg(algo: str, quick: bool, reducer: str) -> TrainConfig:
    T1 = 512 if quick else 2048
    kw = dict(eta1=0.5, iid=True, batch_per_client=32, seed=0,
              reducer=reducer, topk_frac=0.125)
    if algo == "sync":
        return TrainConfig(algo=algo, T1=T1 * 2, k1=1.0, n_stages=1, **kw)
    if algo == "local":
        return TrainConfig(algo=algo, T1=T1, k1=8.0, n_stages=2, **kw)
    if algo == "stl_nc1":
        return TrainConfig(algo=algo, T1=T1 // 4, k1=2.0, n_stages=6,
                           gamma_inv=0.1, **kw)
    return TrainConfig(algo=algo, T1=T1 // 4, k1=2.0, n_stages=6, **kw)


def run_hierarchical(loss_fn, eval_fn, p0, data, n_clients: int,
                     quick: bool):
    """The hierarchical column pair: simulator vs driver, same config.

    Returns two rows. Rounds must match (same stage stream) and modeled
    bytes must be bit-identical (both front-ends price the same
    ``engine.Hierarchical`` topology — the driver's from its executed
    two-level sync step's tags); asserted here so the bench doubles as the
    smoke test for the hierarchical driver path.
    """
    T1 = 512 if quick else 2048
    cfg = TrainConfig(algo="stl_sc", eta1=0.5, T1=T1 // 4, k1=2.0,
                      n_stages=6, iid=True, batch_per_client=32, seed=0,
                      topology="hier", reducer="dense", inter_reducer="int8",
                      n_pods=2)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=64)
    summ = comm_summary_for(cfg, p0, n_clients, hist[-1].round)
    sim_row = {"algo": "stl_sc/hier", "reducer": summ["reducer"],
               "backend": "simulator", "rounds": hist[-1].round,
               "iters": hist[-1].iteration, "final_obj": hist[-1].value,
               "comm_bytes": summ["total_bytes"],
               "comm_time_s": summ["total_time_s"]}

    train_step = make_client_sgd_step(loss_fn, data, batch=32)
    sync_step = LS.build_sync_step("dense", hierarchical=True, n_pods=2,
                                   inter_reducer="int8")
    ds = StagewiseDriver(cfg, jax.jit(train_step), jax.jit(sync_step)).run(
        driver_state(p0, n_clients), itertools.repeat(None))
    obj = float(eval_fn(jax.tree.map(lambda x: x[0], ds.state["params"])))
    drv_row = {"algo": "stl_sc/hier", "reducer": summ["reducer"],
               "backend": "driver", "rounds": ds.rounds_total,
               "iters": ds.iters_total, "final_obj": obj,
               "comm_bytes": ds.comm_bytes_total,
               "comm_time_s": ds.comm_time_s}
    assert drv_row["rounds"] == sim_row["rounds"], (drv_row, sim_row)
    assert drv_row["comm_bytes"] == sim_row["comm_bytes"], (drv_row, sim_row)
    assert sum(l["bytes"] for l in ds.leaf_ledger) == ds.comm_bytes_total
    for r in (sim_row, drv_row):
        print(f"  {r['algo']:12s} {r['reducer']:10s} [{r['backend']:9s}] "
              f"rounds={r['rounds']:>6} bytes={r['comm_bytes']:.3e} "
              f"t={r['comm_time_s']:.2f}s obj={r['final_obj']:.6f}",
              flush=True)
    return [sim_row, drv_row]


def run(quick: bool = True):
    n_clients = 8 if quick else 32
    loss_fn, eval_fn, p0, data = make_problem(quick, n_clients)
    net = NetworkModel()
    rows = []
    for algo in ALGOS:
        base_obj = None
        base_bytes = None
        for red in REDUCERS:
            cfg = algo_cfg(algo, quick, red)
            hist = simulate.run(loss_fn, p0, data, cfg, eval_fn,
                                eval_every=64)
            summ = comm_summary_for(cfg, p0, n_clients, hist[-1].round)
            row = {"algo": algo, "reducer": summ["reducer"],
                   "rounds": hist[-1].round, "iters": hist[-1].iteration,
                   "final_obj": hist[-1].value,
                   "comm_bytes": summ["total_bytes"],
                   "comm_time_s": summ["total_time_s"]}
            if red == "dense":
                base_obj, base_bytes = row["final_obj"], row["comm_bytes"]
                row["bytes_x"], row["obj_drift"] = "1.0x", "0.0%"
            else:
                ratio = base_bytes / max(row["comm_bytes"], 1)
                drift = abs(row["final_obj"] - base_obj) / abs(base_obj)
                row["bytes_x"] = f"{ratio:.1f}x"
                row["obj_drift"] = f"{drift * 100:.2f}%"
                row["ok"] = (ratio >= MIN_BYTES_RATIO
                             and drift <= MAX_OBJ_DRIFT)
            print(f"  {algo:8s} {row['reducer']:8s} rounds={row['rounds']:>6} "
                  f"bytes={row['comm_bytes']:.3e} t={row['comm_time_s']:.2f}s "
                  f"obj={row['final_obj']:.6f} ({row['bytes_x']}, "
                  f"drift {row['obj_drift']})", flush=True)
            rows.append(row)
    rows.extend(run_hierarchical(loss_fn, eval_fn, p0, data, n_clients,
                                 quick))
    print_table("Table 4 — communication cost (rounds × bytes × modeled time)",
                rows, ["algo", "reducer", "backend", "rounds", "iters",
                       "final_obj", "comm_bytes", "comm_time_s", "bytes_x",
                       "obj_drift"])
    bad = [r for r in rows if r.get("ok") is False]
    assert not bad, f"compressed reducers missed the bytes/objective bar: {bad}"
    save_artifact("table4_comm_cost", rows)
    save_bench("table4_comm_cost", rows,
               meta={"network": {"latency_s": net.latency_s,
                                 "bandwidth_gbps": net.bandwidth_gbps},
                     "n_clients": n_clients,
                     "scale": "quick" if quick else "full"})
    return rows


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)
