"""Table 5 (beyond-paper): stragglers, async merging, and the event clock.

The paper (and Tables 1–4) count communication *rounds*; rounds are the
wrong unit once clients are heterogeneous — a synchronous round costs the
slowest client's compute plus the barrier. This table runs every cell on
``repro.runtime``'s discrete-event clock, so STL-SGD's growing k_s and
barrier-free AsyncPeriod merging are priced in the same modeled wall-clock
seconds:

  {sync, async} × {dense, int8 messages} × straggler severity (1×/2×/4×)

with a fixed straggler cohort (25% of clients). The claim under test: at
≥2× straggler slowdown, AsyncPeriod beats the synchronous schedule on
modeled wall-clock (the stage budget is work-conserving — fast clients keep
stepping while stragglers lag, and their late deltas merge with
staleness-decayed weights) at <1% final-objective drift.

    PYTHONPATH=src python -m benchmarks.table5_straggler [--smoke|--full]
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, save_artifact, save_bench
from repro.configs.base import TrainConfig
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg
from repro import runtime

ALGOS = ("local", "stl_sc")
MODES = ("sync", "async")
REDUCERS = ("dense", "int8")
SLOWDOWNS = (1.0, 2.0, 4.0)
STRAGGLER_FRAC = 0.25

# acceptance threshold (also asserted by tests/test_runtime.py)
MAX_OBJ_DRIFT = 0.01


def make_problem(scale: str, n_clients: int):
    n, d = {"smoke": (1024, 32), "quick": (4096, 64),
            "full": (16384, 123)}[scale]
    x, y = make_binary_classification(n=n, d=d, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, n_clients, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, logreg.init_params(None, d), data


def algo_cfg(algo: str, scale: str, reducer: str, mode: str,
             slowdown: float) -> TrainConfig:
    T1 = {"smoke": 64, "quick": 256, "full": 1024}[scale]
    kw = dict(eta1=0.5, iid=True, batch_per_client=32, seed=0,
              reducer=reducer, async_mode=mode == "async",
              straggler_frac=STRAGGLER_FRAC if slowdown > 1.0 else 0.0,
              straggler_slowdown=slowdown, base_step_time_s=1e-3)
    if algo == "local":
        return TrainConfig(algo=algo, T1=T1, k1=8.0, n_stages=2, **kw)
    return TrainConfig(algo=algo, T1=T1 // 4, k1=2.0, n_stages=6, **kw)


def run(scale: str = "quick"):
    n_clients = 8
    loss_fn, eval_fn, p0, data = make_problem(scale, n_clients)
    rows = []
    sync_ref = {}  # (algo, reducer, slowdown) -> (wall, obj)
    for algo in ALGOS:
        for red in REDUCERS:
            for slow in SLOWDOWNS:
                for mode in MODES:
                    cfg = algo_cfg(algo, scale, red, mode, slow)
                    res = runtime.run(loss_fn, p0, data, cfg, eval_fn,
                                      eval_every=16)
                    # one comparable work unit: total local steps across
                    # clients (the sync engine counts vmapped cohort slots,
                    # the async engine counts per-client job steps)
                    steps = res.iters * (n_clients if mode == "sync" else 1)
                    row = {"algo": algo, "mode": mode, "reducer": red,
                           "slowdown": slow, "rounds": res.rounds,
                           "client_steps": steps,
                           "wall_clock_s": res.wall_clock_s,
                           "final_obj": res.history[-1].value,
                           "comm_bytes": res.comm_bytes,
                           "comm_time_s": res.comm_time_s}
                    if mode == "sync":
                        sync_ref[(algo, red, slow)] = (res.wall_clock_s,
                                                       res.history[-1].value)
                        row["speedup"], row["obj_drift"] = "1.00x", "0.00%"
                    else:
                        w0, o0 = sync_ref[(algo, red, slow)]
                        speed = w0 / max(res.wall_clock_s, 1e-12)
                        drift = abs(res.history[-1].value - o0) / abs(o0)
                        row["speedup"] = f"{speed:.2f}x"
                        row["obj_drift"] = f"{drift * 100:.2f}%"
                        # the acceptance bar: barrier-free merging must win
                        # wall-clock under real stragglers without moving
                        # the objective
                        if slow >= 2.0:
                            row["ok"] = (speed > 1.0
                                         and drift <= MAX_OBJ_DRIFT)
                    print(f"  {algo:7s} {mode:5s} {red:5s} {slow:.0f}x "
                          f"rounds={row['rounds']:>5} "
                          f"wall={row['wall_clock_s']:8.3f}s "
                          f"obj={row['final_obj']:.6f} "
                          f"({row['speedup']}, drift {row['obj_drift']})",
                          flush=True)
                    rows.append(row)
    print_table("Table 5 — stragglers: objective vs modeled wall-clock "
                "(discrete-event runtime)",
                rows, ["algo", "mode", "reducer", "slowdown", "rounds",
                       "client_steps", "wall_clock_s", "final_obj",
                       "speedup", "obj_drift"])
    bad = [r for r in rows if r.get("ok") is False]
    assert not bad, \
        f"async missed the wall-clock/objective bar under stragglers: {bad}"
    save_artifact("table5_straggler", rows)
    save_bench("table5_straggler", rows,
               meta={"scale": scale, "n_clients": n_clients,
                     "straggler_frac": STRAGGLER_FRAC,
                     "hetero": dataclasses.asdict(
                         runtime.Heterogeneity.from_config(
                             algo_cfg("local", scale, "dense", "sync", 2.0)))})
    return rows


if __name__ == "__main__":
    import sys

    scale = ("smoke" if "--smoke" in sys.argv
             else "full" if "--full" in sys.argv else "quick")
    run(scale)
