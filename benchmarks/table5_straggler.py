"""Table 5 (beyond-paper): stragglers, async merging, and the event clock.

The paper (and Tables 1–4) count communication *rounds*; rounds are the
wrong unit once clients are heterogeneous — a synchronous round costs the
slowest client's compute plus the barrier. This table runs every cell on
``repro.runtime``'s discrete-event clock, so STL-SGD's growing k_s and
barrier-free AsyncPeriod merging are priced in the same modeled wall-clock
seconds:

  {sync, async} × {dense, int8 messages} × straggler severity (1×/2×/4×)

with a fixed straggler cohort (25% of clients). The claim under test: at
≥2× straggler slowdown, AsyncPeriod beats the synchronous schedule on
modeled wall-clock (the stage budget is work-conserving — fast clients keep
stepping while stragglers lag, and their late deltas merge with
staleness-decayed weights) at <1% final-objective drift.

The second half is the streaming axis on a multi-leaf MLP (8 leaves),
three tables deep:

  * 5b {blocking, streaming} uploads: per-leaf uploads start as each
    layer's last local step completes (reverse-layer order,
    ``runtime.StreamingSchedule``) instead of one monolithic message
    after compute_done, so upload overlaps the final step's remaining
    backward compute. Dense streaming ≥ 1.2× modeled wall-clock over
    blocking at every slowdown; int8 messages shrink the β term that
    streaming hides, so their overlap win is asserted looser (≥ 1.05×) —
    compression and overlap attack the same bytes.
  * 5c the downlink (``count_downlink=True``): the billed consensus
    broadcast streams per leaf in server-completion order instead of one
    dense monolith after the merge. The broadcast doesn't compress, so
    the win survives message compression (≥ 1.15× dense / 1.1× int8).
  * 5d streaming∘hierarchical (2 pods, billed downlink): full streaming
    — per-leaf intra uploads + per-leaf WAN forwarding + per-leaf
    broadcast — must compound the uplink-only comparator's win at ≥2×
    stragglers (``StreamingSchedule(uplink_only=True)``, the PR-4
    semantics kept addressable as ``upload_schedule="streaming-uplink"``).

Everywhere: parameter trajectories bit-exact across schedules and
topology streaming variants (streaming is pure clock accounting), and
the per-(leaf, hop) comm ledger — uplink, intra/inter-pod, downlink —
reconciling with the blocking tree-level totals (bytes exactly, seconds
to float-sum precision).

    PYTHONPATH=src python -m benchmarks.table5_straggler \\
        [--smoke|--full] [--streaming] [--trace out.json]

``--streaming`` runs *only* the {blocking, streaming} axis and
``--no-streaming`` only the {sync, async} table (CI's bench-smoke drives
the two as separate ``--smoke --no-streaming`` / ``--smoke --streaming``
steps); without flags both tables run.

``--trace out.json`` threads a ``repro.obs.Tracer`` through every runtime
run and exports the merged span timeline as a Perfetto-loadable Chrome
trace (open at ui.perfetto.dev). Before writing it, the virtual-clock
``reduce_leaf`` spans are reconciled bit-exactly (bytes) against the
streaming runs' ``leaf_ledger`` — the trace is asserted to be the ledger,
not a parallel approximation of it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_artifact, save_bench
from repro.configs.base import TrainConfig
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg, mlp
from repro import runtime

ALGOS = ("local", "stl_sc")
MODES = ("sync", "async")
REDUCERS = ("dense", "int8")
SLOWDOWNS = (1.0, 2.0, 4.0)
STRAGGLER_FRAC = 0.25

# acceptance threshold (also asserted by tests/test_runtime.py)
MAX_OBJ_DRIFT = 0.01
# streaming overlap acceptance: dense hides the full β term behind the
# final step's backward pass; int8's β term is ~4× smaller, so less is
# left to hide (see docs/streaming.md)
MIN_STREAM_SPEEDUP = {"dense": 1.2, "int8": 1.05}
# downlink-billed rounds: streaming additionally hides the (always-dense)
# consensus broadcast behind the server's own merging, so the bar holds
# for both reducers — the downlink payload doesn't compress
MIN_DOWNLINK_SPEEDUP = {"dense": 1.15, "int8": 1.1}
# streaming∘hierarchical: streaming the WAN hop + downlink must compound
# the uplink-only overlap win at >=2x stragglers (measured ≥1.4x; the bar
# leaves headroom for link-model recalibration)
MIN_WAN_COMPOUND_GAIN = 1.2


def make_problem(scale: str, n_clients: int):
    n, d = {"smoke": (1024, 32), "quick": (4096, 64),
            "full": (16384, 123)}[scale]
    x, y = make_binary_classification(n=n, d=d, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, n_clients, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, logreg.init_params(None, d), data


def algo_cfg(algo: str, scale: str, reducer: str, mode: str,
             slowdown: float) -> TrainConfig:
    T1 = {"smoke": 64, "quick": 256, "full": 1024}[scale]
    kw = dict(eta1=0.5, iid=True, batch_per_client=32, seed=0,
              reducer=reducer, async_mode=mode == "async",
              straggler_frac=STRAGGLER_FRAC if slowdown > 1.0 else 0.0,
              straggler_slowdown=slowdown, base_step_time_s=1e-3)
    if algo == "local":
        return TrainConfig(algo=algo, T1=T1, k1=8.0, n_stages=2, **kw)
    return TrainConfig(algo=algo, T1=T1 // 4, k1=2.0, n_stages=6, **kw)


def make_mlp_problem(scale: str, n_clients: int):
    """Multi-leaf (8-leaf MLP) problem for the streaming-overlap axis."""
    n = {"smoke": 512, "quick": 1024, "full": 4096}[scale]
    x, y = make_binary_classification(n=n, d=96, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, n_clients, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: mlp.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: mlp.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, mlp.init_params(jax.random.key(42), 96), data


def streaming_cfg(reducer: str, schedule: str, slowdown: float) -> TrainConfig:
    # k = 1 (EveryStep): the small-k regime where upload cost is a large
    # fraction of the round — exactly where overlap pays. Link: datacenter
    # latency, bandwidth such that one dense model ≈ 2 local steps.
    return TrainConfig(algo="sync", eta1=0.1, T1=32, n_stages=2,
                       batch_per_client=32, seed=0, reducer=reducer,
                       upload_schedule=schedule,
                       comm_latency_s=1e-4, comm_bandwidth_gbps=0.45,
                       base_step_time_s=1e-3,
                       straggler_frac=STRAGGLER_FRAC if slowdown > 1.0
                       else 0.0,
                       straggler_slowdown=slowdown)


def _accumulate_trace_expect(expect, res, schedule: str) -> None:
    """Fold one traced run's leaf_ledger into the per-span-name byte
    totals the exported trace must reconcile against (see export_trace).

    Per-leaf client uploads (and the streamed WAN hop) appear as
    ``reduce_leaf`` spans; the streamed downlink as ``broadcast_leaf``;
    a billed monolithic downlink as ``broadcast`` transfer spans."""
    if expect is None:
        return
    rows = res.leaf_ledger or []
    if schedule in ("streaming", "streaming-uplink"):
        expect["reduce_leaf"] += sum(
            r["bytes"] for r in rows if r["hop"] in ("uplink", "intra_pod"))
    if schedule == "streaming":
        # only the full streaming schedule streams the inter-pod WAN hop
        expect["reduce_leaf"] += sum(
            r["bytes"] for r in rows if r["hop"] == "inter_pod")
    down = sum(r["bytes"] for r in rows if r["hop"] == "downlink")
    if down:
        key = "broadcast_leaf" if schedule == "streaming" else "broadcast"
        expect[key] += down


def _assert_bit_exact(results: dict, label: str) -> bool:
    """All runs in ``results`` must share params and (round, objective)
    history bit-exactly — the schedule/topology axes are pure clock."""
    ref_name = next(iter(results))
    ref = results[ref_name]
    for name, res in results.items():
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(ref.params),
                                   jax.tree.leaves(res.params)))
        assert same, f"{label}: {name} diverged from {ref_name}"
        assert [(h.round, h.value) for h in ref.history] \
            == [(h.round, h.value) for h in res.history], \
            f"{label}: {name} history diverged from {ref_name}"
    return True


def run_downlink(scale: str = "quick", tracer=None, expect=None):
    """The downlink axis: billed consensus broadcasts, streamed per leaf.

    ``count_downlink=True`` prices the server→client broadcast of every
    round. Blocking ships it as one dense monolith after the merge;
    streaming ships leaf l as soon as the server finishes reducing it, so
    the next round starts ~one leaf (not one model) after the merge. The
    broadcast is dense for every reducer, so — unlike the uplink axis —
    the overlap win survives message compression."""
    n_clients = 8
    loss_fn, eval_fn, p0, data = make_mlp_problem(scale, n_clients)
    n_leaves = len(jax.tree.leaves(p0))
    rows = []
    print(f"\ndownlink axis — billed dense broadcast, streamed per leaf:")
    for red in REDUCERS:
        for slow in SLOWDOWNS:
            res = {}
            for sched in ("blocking", "streaming"):
                cfg = dataclasses.replace(streaming_cfg(red, sched, slow),
                                          count_downlink=True)
                res[sched] = runtime.run(loss_fn, p0, data, cfg, eval_fn,
                                         eval_every=16, tracer=tracer)
                _accumulate_trace_expect(expect, res[sched], sched)
            blk, stm = res["blocking"], res["streaming"]
            _assert_bit_exact(res, f"downlink ({red}, {slow}x)")
            speed = blk.wall_clock_s / max(stm.wall_clock_s, 1e-12)
            # the ledger now carries downlink rows, and still reconciles
            hops = {l["hop"] for l in stm.leaf_ledger}
            assert hops == {"uplink", "downlink"}, hops
            leaf_bytes = sum(l["bytes"] for l in stm.leaf_ledger)
            assert leaf_bytes == blk.comm_bytes, (leaf_bytes, blk.comm_bytes)
            leaf_time = sum(l["time_s"] for l in stm.leaf_ledger)
            assert abs(leaf_time - blk.comm_time_s) \
                <= 1e-9 * max(blk.comm_time_s, 1.0)
            down_bytes = sum(l["bytes"] for l in stm.leaf_ledger
                             if l["hop"] == "downlink")
            ok = speed >= MIN_DOWNLINK_SPEEDUP[red]
            rows.append({"reducer": red, "slowdown": slow,
                         "leaves": n_leaves, "rounds": stm.rounds,
                         "blocking_s": blk.wall_clock_s,
                         "streaming_s": stm.wall_clock_s,
                         "speedup": f"{speed:.2f}x",
                         "downlink_bytes": down_bytes, "ok": ok})
            print(f"  {red:5s} {slow:.0f}x blocking={blk.wall_clock_s:8.4f}s "
                  f"streaming={stm.wall_clock_s:8.4f}s ({speed:.2f}x)",
                  flush=True)
    print_table("Table 5c — streamed downlink vs monolithic broadcast "
                "(count_downlink=True, trajectories bit-exact)",
                rows, ["reducer", "slowdown", "leaves", "rounds",
                       "blocking_s", "streaming_s", "speedup",
                       "downlink_bytes"])
    bad = [r for r in rows if not r["ok"]]
    assert not bad, \
        f"streamed downlink missed the overlap bar {MIN_DOWNLINK_SPEEDUP}: {bad}"
    save_artifact("table5_downlink", rows)
    save_bench("table5_downlink", rows,
               meta={"scale": scale, "n_clients": n_clients,
                     "n_leaves": n_leaves,
                     "straggler_frac": STRAGGLER_FRAC,
                     "min_speedup": MIN_DOWNLINK_SPEEDUP})
    return rows


def run_hier_streaming(scale: str = "quick", tracer=None, expect=None):
    """The streaming∘hierarchical axis: compose every overlap.

    Three schedules over the two-level (2-pod) round with billed
    downlink: blocking (serial intra hop, serial WAN hop, monolithic
    broadcast), streaming-uplink (per-leaf intra uploads only — the
    uplink-only comparator), and full streaming (per-leaf intra uploads,
    per-leaf WAN forwarding overlapping the intra reduction of later
    leaves, per-leaf broadcast). Params are bit-exact across all three
    (``Hierarchical(streaming=True)`` folds the same per-leaf rng as the
    blocking two-level round); at >=2x stragglers the full composition
    must compound the uplink-only win."""
    n_clients, n_pods = 8, 2
    loss_fn, eval_fn, p0, data = make_mlp_problem(scale, n_clients)
    n_leaves = len(jax.tree.leaves(p0))
    schedules = ("blocking", "streaming-uplink", "streaming")
    rows = []
    print(f"\nstreaming∘hierarchical axis — {n_pods}-pod two-level round, "
          "WAN hop + downlink streamed per leaf:")
    for red in REDUCERS:
        for slow in SLOWDOWNS:
            res = {}
            for sched in schedules:
                cfg = dataclasses.replace(
                    streaming_cfg(red, sched, slow),
                    topology="streaming-hier", n_pods=n_pods,
                    inter_reducer=red, count_downlink=True)
                res[sched] = runtime.run(loss_fn, p0, data, cfg, eval_fn,
                                         eval_every=16, tracer=tracer)
                _accumulate_trace_expect(expect, res[sched], sched)
            blk, up, full = (res["blocking"], res["streaming-uplink"],
                             res["streaming"])
            _assert_bit_exact(res, f"streaming∘hier ({red}, {slow}x)")
            # the two-level per-leaf ledger reconciles across all 3 hops
            hops = {l["hop"] for l in full.leaf_ledger}
            assert hops == {"intra_pod", "inter_pod", "downlink"}, hops
            leaf_bytes = sum(l["bytes"] for l in full.leaf_ledger)
            assert leaf_bytes == blk.comm_bytes, (leaf_bytes, blk.comm_bytes)
            speed_up = blk.wall_clock_s / max(up.wall_clock_s, 1e-12)
            speed_full = blk.wall_clock_s / max(full.wall_clock_s, 1e-12)
            gain = up.wall_clock_s / max(full.wall_clock_s, 1e-12)
            # ISSUE acceptance: the composition compounds the uplink-only
            # overlap win under real stragglers
            ok = (slow < 2.0
                  or (speed_up > 1.0 and gain >= MIN_WAN_COMPOUND_GAIN))
            rows.append({"reducer": red, "slowdown": slow,
                         "leaves": n_leaves, "rounds": full.rounds,
                         "blocking_s": blk.wall_clock_s,
                         "uplink_only_s": up.wall_clock_s,
                         "full_stream_s": full.wall_clock_s,
                         "speedup_uplink": f"{speed_up:.2f}x",
                         "speedup_full": f"{speed_full:.2f}x",
                         "wan_gain": f"{gain:.2f}x", "ok": ok})
            print(f"  {red:5s} {slow:.0f}x blocking={blk.wall_clock_s:8.4f}s "
                  f"uplink-only={up.wall_clock_s:8.4f}s "
                  f"full={full.wall_clock_s:8.4f}s "
                  f"(up {speed_up:.2f}x, full {speed_full:.2f}x)",
                  flush=True)
    print_table("Table 5d — streaming∘hierarchical: uplink-only vs full "
                "per-leaf round (2 pods, billed downlink, bit-exact)",
                rows, ["reducer", "slowdown", "rounds", "blocking_s",
                       "uplink_only_s", "full_stream_s", "speedup_uplink",
                       "speedup_full", "wan_gain"])
    bad = [r for r in rows if not r["ok"]]
    assert not bad, \
        (f"full streaming failed to compound the uplink-only win by "
         f">={MIN_WAN_COMPOUND_GAIN}x at >=2x stragglers: {bad}")
    save_artifact("table5_hier_streaming", rows)
    save_bench("table5_hier_streaming", rows,
               meta={"scale": scale, "n_clients": n_clients,
                     "n_pods": n_pods, "n_leaves": n_leaves,
                     "straggler_frac": STRAGGLER_FRAC,
                     "min_wan_gain": MIN_WAN_COMPOUND_GAIN})
    return rows


def run_streaming(scale: str = "quick", tracer=None, expect=None):
    """The {blocking, streaming} axis: per-leaf overlap on a multi-leaf MLP."""
    n_clients = 8
    loss_fn, eval_fn, p0, data = make_mlp_problem(scale, n_clients)
    n_leaves = len(jax.tree.leaves(p0))
    assert n_leaves >= 4, n_leaves
    rows = []
    print(f"\nstreaming axis — {n_leaves}-leaf MLP, per-leaf uploads "
          "overlap the final local step:")
    for red in REDUCERS:
        for slow in SLOWDOWNS:
            res = {}
            for sched in ("blocking", "streaming"):
                res[sched] = runtime.run(loss_fn, p0, data,
                                         streaming_cfg(red, sched, slow),
                                         eval_fn, eval_every=16,
                                         tracer=tracer)
                _accumulate_trace_expect(expect, res[sched], sched)
            blk, stm = res["blocking"], res["streaming"]
            speed = blk.wall_clock_s / max(stm.wall_clock_s, 1e-12)
            # streaming is pure clock accounting: same seed ⇒ identical
            # parameters and identical (round, objective) trajectory
            bit_exact = all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(blk.params),
                                jax.tree.leaves(stm.params)))
            assert bit_exact, \
                f"streaming changed the trajectory ({red}, {slow}x)"
            assert [(h.round, h.value) for h in blk.history] \
                == [(h.round, h.value) for h in stm.history]
            # per-leaf ledger reconciles with the blocking tree-level totals
            leaf_bytes = sum(l["bytes"] for l in stm.leaf_ledger)
            leaf_time = sum(l["time_s"] for l in stm.leaf_ledger)
            assert leaf_bytes == blk.comm_bytes, \
                (leaf_bytes, blk.comm_bytes)
            assert abs(leaf_time - blk.comm_time_s) \
                <= 1e-9 * max(blk.comm_time_s, 1.0), \
                (leaf_time, blk.comm_time_s)
            ok = speed >= MIN_STREAM_SPEEDUP[red]
            rows.append({"reducer": red, "slowdown": slow,
                         "leaves": n_leaves, "rounds": stm.rounds,
                         "blocking_s": blk.wall_clock_s,
                         "streaming_s": stm.wall_clock_s,
                         "speedup": f"{speed:.2f}x",
                         "bit_exact": bit_exact,
                         "leaf_bytes": leaf_bytes, "ok": ok})
            print(f"  {red:5s} {slow:.0f}x blocking={blk.wall_clock_s:8.4f}s "
                  f"streaming={stm.wall_clock_s:8.4f}s ({speed:.2f}x, "
                  f"bit-exact={bit_exact})", flush=True)
    print_table("Table 5b — streaming per-leaf uploads vs blocking "
                "(modeled wall-clock, trajectories bit-exact)",
                rows, ["reducer", "slowdown", "leaves", "rounds",
                       "blocking_s", "streaming_s", "speedup", "bit_exact"])
    bad = [r for r in rows if not r["ok"]]
    assert not bad, \
        f"streaming missed the overlap bar (dense >=1.2x, int8 >=1.05x): {bad}"
    save_artifact("table5_streaming", rows)
    save_bench("table5_streaming", rows,
               meta={"scale": scale, "n_clients": n_clients,
                     "n_leaves": n_leaves,
                     "straggler_frac": STRAGGLER_FRAC,
                     "min_speedup": MIN_STREAM_SPEEDUP})
    return rows


def run(scale: str = "quick", tracer=None):
    n_clients = 8
    loss_fn, eval_fn, p0, data = make_problem(scale, n_clients)
    rows = []
    sync_ref = {}  # (algo, reducer, slowdown) -> (wall, obj)
    for algo in ALGOS:
        for red in REDUCERS:
            for slow in SLOWDOWNS:
                for mode in MODES:
                    cfg = algo_cfg(algo, scale, red, mode, slow)
                    res = runtime.run(loss_fn, p0, data, cfg, eval_fn,
                                      eval_every=16, tracer=tracer)
                    # one comparable work unit: total local steps across
                    # clients (the sync engine counts vmapped cohort slots,
                    # the async engine counts per-client job steps)
                    steps = res.iters * (n_clients if mode == "sync" else 1)
                    row = {"algo": algo, "mode": mode, "reducer": red,
                           "slowdown": slow, "rounds": res.rounds,
                           "client_steps": steps,
                           "wall_clock_s": res.wall_clock_s,
                           "final_obj": res.history[-1].value,
                           "comm_bytes": res.comm_bytes,
                           "comm_time_s": res.comm_time_s}
                    if mode == "sync":
                        sync_ref[(algo, red, slow)] = (res.wall_clock_s,
                                                       res.history[-1].value)
                        row["speedup"], row["obj_drift"] = "1.00x", "0.00%"
                    else:
                        w0, o0 = sync_ref[(algo, red, slow)]
                        speed = w0 / max(res.wall_clock_s, 1e-12)
                        drift = abs(res.history[-1].value - o0) / abs(o0)
                        row["speedup"] = f"{speed:.2f}x"
                        row["obj_drift"] = f"{drift * 100:.2f}%"
                        # the acceptance bar: barrier-free merging must win
                        # wall-clock under real stragglers without moving
                        # the objective
                        if slow >= 2.0:
                            row["ok"] = (speed > 1.0
                                         and drift <= MAX_OBJ_DRIFT)
                    print(f"  {algo:7s} {mode:5s} {red:5s} {slow:.0f}x "
                          f"rounds={row['rounds']:>5} "
                          f"wall={row['wall_clock_s']:8.3f}s "
                          f"obj={row['final_obj']:.6f} "
                          f"({row['speedup']}, drift {row['obj_drift']})",
                          flush=True)
                    rows.append(row)
    print_table("Table 5 — stragglers: objective vs modeled wall-clock "
                "(discrete-event runtime)",
                rows, ["algo", "mode", "reducer", "slowdown", "rounds",
                       "client_steps", "wall_clock_s", "final_obj",
                       "speedup", "obj_drift"])
    bad = [r for r in rows if r.get("ok") is False]
    assert not bad, \
        f"async missed the wall-clock/objective bar under stragglers: {bad}"
    save_artifact("table5_straggler", rows)
    save_bench("table5_straggler", rows,
               meta={"scale": scale, "n_clients": n_clients,
                     "straggler_frac": STRAGGLER_FRAC,
                     "hetero": dataclasses.asdict(
                         runtime.Heterogeneity.from_config(
                             algo_cfg("local", scale, "dense", "sync", 2.0)))})
    return rows


def _parse_trace(argv):
    for i, a in enumerate(argv):
        if a == "--trace":
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                raise SystemExit("--trace needs a path, e.g. --trace out.json")
            return argv[i + 1]
        if a.startswith("--trace="):
            return a.split("=", 1)[1]
    return None


def export_trace(tracer, path: str, expect):
    """Write the Chrome trace, after reconciling it against the ledger.

    Every per-leaf transfer the event runtime scheduled appears as a
    virtual-clock span — ``reduce_leaf`` (uplink, intra-pod, streamed WAN
    hop), ``broadcast_leaf`` (streamed downlink), ``broadcast`` (billed
    monolithic downlink) — and each family must sum, in bytes and
    bit-exactly, to the matching ``leaf_ledger`` rows accumulated by the
    runs (``_accumulate_trace_expect``). A trace that disagrees with the
    comm ledger would be decoration, not observability.
    """
    from repro.obs import VIRTUAL, write_chrome_trace, write_jsonl

    recon = {}
    for name, want in expect.items():
        got = sum(int(s.attrs["bytes"]) for s in tracer.spans
                  if s.name == name and s.clock == VIRTUAL
                  and "bytes" in s.attrs)
        assert got == want, \
            f"trace {name} bytes {got} != leaf_ledger bytes {want}"
        recon[name] = got
    write_chrome_trace(tracer, path)
    write_jsonl(tracer, path + "l")   # out.json -> out.jsonl
    print(f"\ntrace: {len(tracer.spans)} spans -> {path} "
          f"(span bytes reconcile with leaf_ledger: {recon}); "
          "open at ui.perfetto.dev")


if __name__ == "__main__":
    import sys

    scale = ("smoke" if "--smoke" in sys.argv
             else "full" if "--full" in sys.argv else "quick")
    trace_path = _parse_trace(sys.argv)
    tracer = None
    if trace_path:
        from repro.obs import Tracer
        tracer = Tracer(run_id="table5")
    expect = ({"reduce_leaf": 0, "broadcast_leaf": 0, "broadcast": 0}
              if tracer is not None else None)
    if "--streaming" not in sys.argv:
        run(scale, tracer=tracer)
    if "--no-streaming" not in sys.argv:
        run_streaming(scale, tracer=tracer, expect=expect)
        run_downlink(scale, tracer=tracer, expect=expect)
        run_hier_streaming(scale, tracer=tracer, expect=expect)
    if tracer is not None:
        export_trace(tracer, trace_path, expect)
