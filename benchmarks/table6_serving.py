"""Table 6 (beyond-paper): serving throughput/latency under open-loop load.

Tables 1–5 price *training*; this table prices what the trained model is
for. It sweeps offered load over the continuous-batching serve driver
(``repro.serve``) and reports the throughput/latency frontier:

  {poisson, bursty} arrivals × load ∈ {0.25, 0.5, 0.8, 1.2} × capacity

where *capacity* is the modeled roofline decode rate of the slot pool
(``n_slots / decode_step_s``, ``launch/flops.py`` pricing). Load 1.2 is
deliberately past saturation — open-loop arrivals keep coming whether or
not the server keeps up, so the p95/p99 end-to-end latency shows the
hockey-stick the paper-style round counting can't see, while throughput
plateaus at capacity.

Every latency column is a *modeled* (virtual-clock) number — a pure
function of the traffic seed, scheduler config and roofline pricing,
independent of host speed and even of the computed logits (retirement
counts tokens, it never inspects them) — so the committed baseline gates
bit-stable in CI (``tools/bench_diff.py``: ``wall_clock_s``, ``p50_s``,
``p95_s``, ``p99_s``, ``slo_breach_s``). Measured host wall-clock and
tok/s ride along in non-monitored columns for the modeled-vs-measured
comparison.

Each cell additionally runs the sliding-window SLO monitor (``obs.slo``,
thresholds in decode-step units) over the cell's virtual-clock series:
``slo_ttb_s`` is the time-to-first-breach (None below the knee —
higher-is-better, so reported but NOT gated), ``slo_breach_s`` the total
breached seconds (higher-is-worse, gated), ``saturated`` whether some
SLO was still breaching when the trace ended — the open-loop saturation
detector.

Percentiles come from the ``serve.*`` obs histograms (exact, numpy-equal
linear interpolation — see ``repro.obs.metrics``), not from ad-hoc math in
this script.

    PYTHONPATH=src python -m benchmarks.table6_serving \\
        [--smoke|--full] [--trace out.json]

``--trace`` exports the bursty cell at the highest load as a
Perfetto-loadable Chrome trace: per-request ``request > {queue, prefill,
decode}`` lifecycle tracks and the SLO breach spans next to the engine's
``decode_step`` occupancy track, plus counter tracks for queue depth,
batch occupancy and tokens/s (open at ui.perfetto.dev).
"""
from __future__ import annotations

import jax

from benchmarks.common import print_table, save_artifact, save_bench
from repro.configs import get_arch
from repro.models import transformer as TF
from repro.obs.metrics import MetricsRegistry
from repro.obs.series import SeriesRegistry
from repro.obs.slo import SLOMonitor, serve_slo_targets
from repro.serve import (
    SchedulerConfig,
    ServeEngine,
    TrafficConfig,
    generate_requests,
)

ARCH = "qwen3-14b"
PROCESSES = ("poisson", "bursty")
LOADS = (0.25, 0.5, 0.8, 1.2)     # × modeled capacity; 1.2 = past saturation
TRACED_CELL = ("bursty", 1.2)     # the cell --trace exports
# the traced cell's series (counter tracks), filled by run() for __main__
TRACED_SERIES: list = []


def scale_params(scale: str) -> dict:
    return {
        "smoke": dict(n_requests=24, n_slots=4, max_seq_len=64,
                      mean_prompt_len=8, max_prompt_len=24,
                      mean_out_len=6, max_out_len=16),
        "quick": dict(n_requests=64, n_slots=8, max_seq_len=128,
                      mean_prompt_len=16, max_prompt_len=48,
                      mean_out_len=12, max_out_len=32),
        "full": dict(n_requests=256, n_slots=8, max_seq_len=256,
                     mean_prompt_len=32, max_prompt_len=96,
                     mean_out_len=24, max_out_len=64),
    }[scale]


def run(scale: str = "quick", tracer=None, seed: int = 0):
    p = scale_params(scale)
    cfg = get_arch(ARCH, smoke=scale != "full")
    params = TF.init_params(jax.random.PRNGKey(seed), cfg)
    sched = SchedulerConfig(n_slots=p["n_slots"],
                            max_seq_len=p["max_seq_len"],
                            max_queue=4 * p["n_requests"])
    engine = ServeEngine(cfg, params, scheduler=sched)
    capacity = p["n_slots"] / engine.decode_step_s   # modeled tok/s ceiling

    rows = []
    print(f"arch={cfg.name} slots={p['n_slots']} "
          f"decode_step={engine.decode_step_s:.3e}s "
          f"capacity={capacity:.0f} tok/s")
    for process in PROCESSES:
        for load in LOADS:
            # offered token rate = load × capacity; requests/s follows from
            # the mean tokens one request asks for
            mean_tokens = p["mean_prompt_len"] + p["mean_out_len"]
            rate_rps = load * capacity / mean_tokens
            tcfg = TrafficConfig(
                process=process, rate_rps=rate_rps,
                n_requests=p["n_requests"],
                mean_prompt_len=p["mean_prompt_len"],
                max_prompt_len=p["max_prompt_len"],
                mean_out_len=p["mean_out_len"],
                max_out_len=p["max_out_len"], seed=seed)
            requests = generate_requests(tcfg, cfg.vocab_size)
            registry = MetricsRegistry()
            series = SeriesRegistry()
            cell_tracer = tracer if (process, load) == TRACED_CELL else None
            rep = engine.run(requests, tracer=cell_tracer, registry=registry,
                             series=series)
            monitor = SLOMonitor(serve_slo_targets(engine.decode_step_s))
            monitor.evaluate(series)
            if cell_tracer is not None:
                monitor.emit_spans(cell_tracer)
                TRACED_SERIES[:] = list(series)
            lat = rep.latency_summary()
            e2e, ttft = lat["serve.e2e_s"], lat["serve.ttft_s"]
            row = {
                "cell": f"{process}@{load:g}",
                "process": process, "load": load,
                "n_requests": len(requests),
                "completed": len(rep.completed),
                "rejected": len(rep.rejected),
                "n_steps": rep.n_steps,
                "occupancy": round(rep.mean_occupancy, 3),
                # modeled, deterministic — the gated columns
                "wall_clock_s": rep.makespan_s,
                "p50_s": e2e["p50"], "p95_s": e2e["p95"],
                "p99_s": e2e["p99"],
                "ttft_p95_s": ttft["p95"],
                "modeled_tok_s": rep.modeled_tok_s,
                # SLO monitor verdicts (modeled): total breached seconds
                # is gated; time-to-breach is higher-is-better (ungated)
                "slo_breach_s": monitor.breach_seconds(),
                "slo_ttb_s": monitor.time_to_breach(),
                "saturated": monitor.saturated(),
                # measured, host-dependent — reported, never gated
                "measured_wall_s": round(rep.measured_wall_s, 3),
                "measured_tok_s": round(rep.measured_tok_s, 1),
            }
            rows.append(row)
            sat = " SAT" if row["saturated"] else ""
            print(f"  {row['cell']:14s} occ={row['occupancy']:5.2f} "
                  f"p50={row['p50_s']:.3e} p95={row['p95_s']:.3e} "
                  f"p99={row['p99_s']:.3e} "
                  f"breach={row['slo_breach_s']:.2e}s{sat} "
                  f"modeled={row['modeled_tok_s']:.0f} tok/s "
                  f"measured={row['measured_tok_s']:.0f} tok/s", flush=True)

    # light acceptance: open-loop latency must show the saturation knee and
    # throughput must track offered load below it
    for process in PROCESSES:
        sub = {r["load"]: r for r in rows if r["process"] == process}
        assert sub[1.2]["p95_s"] >= sub[0.25]["p95_s"], \
            f"{process}: p95 did not grow past saturation: {sub}"
        assert sub[1.2]["occupancy"] >= sub[0.25]["occupancy"], \
            f"{process}: occupancy did not grow with load: {sub}"
        # the saturation detector must fire past the knee and hold below it
        assert sub[1.2]["slo_ttb_s"] is not None, \
            f"{process}: past-saturation load never breached SLOs: {sub[1.2]}"
        assert sub[0.25]["slo_breach_s"] == 0.0, \
            f"{process}: SLO breached below the knee: {sub[0.25]}"
    done = all(r["completed"] + r["rejected"] == r["n_requests"]
               for r in rows)
    assert done, "requests lost: completed + rejected != offered"

    print_table("Table 6 — open-loop serving: offered load vs "
                "throughput/latency (modeled roofline clock)",
                rows, ["cell", "n_requests", "completed", "rejected",
                       "n_steps", "occupancy", "wall_clock_s", "p50_s",
                       "p95_s", "p99_s", "slo_breach_s", "saturated",
                       "modeled_tok_s", "measured_tok_s"])
    save_artifact("table6_serving", rows)
    save_bench("table6_serving", rows,
               meta={"scale": scale, "arch": cfg.name,
                     "n_slots": p["n_slots"],
                     "max_seq_len": p["max_seq_len"],
                     "decode_step_s": engine.decode_step_s,
                     "capacity_tok_s": capacity, "loads": list(LOADS),
                     "slo": {"ttft_p95_steps": 8.0, "e2e_p99_steps": 22.0,
                             "window_steps": 256.0}})
    return rows


def _parse_trace(argv):
    for i, a in enumerate(argv):
        if a == "--trace":
            if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
                raise SystemExit("--trace needs a path, e.g. --trace out.json")
            return argv[i + 1]
        if a.startswith("--trace="):
            return a.split("=", 1)[1]
    return None


if __name__ == "__main__":
    import sys

    scale = ("smoke" if "--smoke" in sys.argv
             else "full" if "--full" in sys.argv else "quick")
    trace_path = _parse_trace(sys.argv)
    tracer = None
    if trace_path:
        from repro.obs import Tracer
        tracer = Tracer(run_id="table6")
    run(scale, tracer=tracer)
    if tracer is not None:
        from repro.obs import write_chrome_trace, write_jsonl

        counters = [s for s in TRACED_SERIES
                    if s.name in ("serve.queue_depth",
                                  "serve.batch_occupancy", "serve.tokens_s")]
        assert len(counters) >= 3, \
            f"traced cell missing counter series: {[s.name for s in counters]}"
        assert any(s.name == "slo_breach" for s in tracer.spans), \
            "traced cell emitted no SLO breach spans"
        write_chrome_trace(tracer, trace_path, series=TRACED_SERIES)
        write_jsonl(tracer, trace_path + "l")
        print(f"\ntrace: {len(tracer.spans)} spans + "
              f"{len(TRACED_SERIES)} counter tracks "
              f"({TRACED_CELL[0]}@{TRACED_CELL[1]:g} cell) -> {trace_path}; "
              "open at ui.perfetto.dev")
