"""Federated Non-IID training — the paper's §5.1 Non-IID protocol end-to-end.

Builds the label-sorted Non-IID partition (s=50% as in the paper), measures
the client gradient diversity ζ, derives the admissible k₁ from Theorem 1's
formula, and runs STL-SGD^sc with the √2 Non-IID stage growth vs Local SGD.
Then composes the stagewise schedule with repro.comm compressed rounds
(int8 / top-k error-feedback reducers) and prices each run with the α–β
network cost model — rounds × bytes × modeled seconds in one table.
Finally re-runs the Non-IID protocol on the discrete-event runtime
(repro.runtime) with a straggler cohort, sync barriers vs AsyncPeriod
merge-on-arrival, priced in modeled wall-clock — and, on a multi-leaf
MLP, blocking vs streaming per-leaf uploads (docs/streaming.md): leaf l's
upload starts as its last local step completes, overlapping the remaining
backward compute, with the trajectory bit-exact across schedules.

    PYTHONPATH=src python examples/federated_noniid.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro import runtime
from repro.comm import comm_summary_for
from repro.configs.base import TrainConfig
from repro.core import schedules, simulate
from repro.data import make_binary_classification
from repro.data.partition import gradient_diversity, partition_paper
from repro.models import logreg, mlp

N = 8
x, y = make_binary_classification(n=8192, d=64, seed=0)
lam = 1e-3
data_np = partition_paper(x, y, N, iid_percent=50.0, seed=1)
data = {k: jnp.asarray(v) for k, v in data_np.items()}
xj, yj = jnp.asarray(x), jnp.asarray(y)

loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
p0 = logreg.init_params(None, 64)

# --- measure the heterogeneity the theory depends on ----------------------
full_grad = lambda p, d: jax.grad(lambda q: loss_fn(q, d))(p)
zeta = float(gradient_diversity(data, full_grad, p0))
print(f"gradient diversity ζ at x0: {zeta:.4f}")

# Theorem 1's admissible k1 (L≈0.25 for logistic features scaled ~1, + λ)
eta1, L = 0.5, 0.5
k1_hom = schedules.theory_k1(eta1, L, N, sigma=1.0, zeta=0.0, iid=False)
k1_non = schedules.theory_k1(eta1, L, N, sigma=1.0, zeta=zeta, iid=False)
print(f"theory k1 (Non-IID formula): ζ=0 → {k1_hom:.2f}, measured ζ → "
      f"{k1_non:.2f} (heterogeneity shrinks the admissible period)")

# --- optimum ---------------------------------------------------------------
p = p0
gd = jax.jit(lambda p: jax.tree.map(lambda a, g: a - 2.0 * g, p,
                                    jax.grad(eval_fn)(p)))
for _ in range(4000):
    p = gd(p)
fstar = float(eval_fn(p))

TARGET = 1e-4
for algo, kw in [
    ("sync", dict(k1=1.0, n_stages=24)),
    ("local", dict(k1=8.0, n_stages=24)),
    ("stl_sc", dict(k1=8.0, n_stages=14)),   # Non-IID: k_{s+1} = √2·k_s
]:
    cfg = TrainConfig(algo=algo, eta1=eta1, T1=512, iid=False,
                      batch_per_client=32, seed=0, **kw)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8,
                        max_rounds=12000, target=fstar + TARGET,
                        lr_alpha=1e-3 if algo in ("sync", "local") else 0.0)
    r = simulate.rounds_to_target(hist, fstar + TARGET)
    print(f"{algo:8s} Non-IID rounds to gap<{TARGET}: {r} "
          f"(final gap {hist[-1].value - fstar:.2e})")

# --- compose stagewise periods with compressed rounds ----------------------
# Fewer rounds (stagewise k_s) × cheaper rounds (compressed reducer): the
# α–β model (5 ms latency, 1 Gbit/s — TrainConfig comm_* defaults) turns
# both into modeled wall-clock.
print("\nreducer   rounds  bytes      modeled_s  final_gap")
for red in ("dense", "int8", "topk"):
    cfg = TrainConfig(algo="stl_sc", eta1=eta1, T1=512, k1=8.0, n_stages=14,
                      iid=False, batch_per_client=32, seed=0, reducer=red)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8,
                        max_rounds=12000, target=fstar + TARGET)
    summ = comm_summary_for(cfg, p0, N, hist[-1].round)
    print(f"{summ['reducer']:9s} {summ['rounds']:6d}  {summ['total_bytes']:9d}"
          f"  {summ['total_time_s']:8.3f}s  {hist[-1].value - fstar:.2e}")

# --- price stragglers on the discrete-event clock (repro.runtime) ----------
# 2 of 8 clients run 4× slower. Synchronous rounds barrier on the
# stragglers every round; AsyncPeriod (cfg.async_mode) lets fast clients
# keep stepping and merges each upload on arrival with staleness-decayed
# weights (comm.StalenessWeightedMean) — same Non-IID problem, same
# schedules, now priced in modeled wall-clock seconds instead of rounds.
print("\nalgo      mode   merges  modeled_s  final_gap")
for algo, kw in [("local", dict(k1=8.0, T1=2048, n_stages=2)),
                 ("stl_sc", dict(k1=8.0, T1=512, n_stages=5))]:
    for mode in ("sync", "async"):
        cfg = TrainConfig(algo=algo, eta1=eta1, iid=False,
                          batch_per_client=32, seed=0,
                          async_mode=mode == "async",
                          straggler_frac=0.25, straggler_slowdown=4.0,
                          base_step_time_s=1e-3, **kw)
        res = runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=64)
        print(f"{algo:9s} {mode:6s} {res.rounds:6d}  "
              f"{res.wall_clock_s:8.3f}s  "
              f"{res.history[-1].value - fstar:.2e}")

# --- stream per-leaf uploads into the final local step ----------------------
# Same straggler cohort, multi-leaf model (8-leaf MLP on the same Non-IID
# features): with upload_schedule="streaming" each leaf's upload starts as
# soon as its last local step completes (reverse-layer order), overlapping
# the remaining backward compute. Pure clock accounting — parameters are
# bit-exact across schedules; only the modeled wall-clock moves. The
# per-leaf ledger (res.leaf_ledger) reconciles with the blocking totals.
print("\nschedule   rounds  modeled_s  final_obj   (8-leaf MLP, 4x "
      "stragglers)")
mlp_loss = lambda p, b: mlp.loss_fn(p, b, lam)
mlp_eval = jax.jit(lambda p: mlp.full_objective(p, xj, yj, lam))
mlp_p0 = mlp.init_params(jax.random.key(42), 64)
stream_cfg = TrainConfig(algo="sync", eta1=0.1, T1=64, n_stages=2, iid=False,
                         batch_per_client=32, seed=0,
                         comm_latency_s=1e-4, comm_bandwidth_gbps=0.45,
                         base_step_time_s=1e-3,
                         straggler_frac=0.25, straggler_slowdown=4.0)
stream_res = {}
for sched in ("blocking", "streaming"):
    cfg = dataclasses.replace(stream_cfg, upload_schedule=sched)
    res = runtime.run(mlp_loss, mlp_p0, data, cfg, mlp_eval, eval_every=32)
    stream_res[sched] = res
    print(f"{sched:9s} {res.rounds:7d}  {res.wall_clock_s:8.3f}s  "
          f"{res.history[-1].value:.6f}")
speed = (stream_res["blocking"].wall_clock_s
         / stream_res["streaming"].wall_clock_s)
same = stream_res["blocking"].history[-1].value \
    == stream_res["streaming"].history[-1].value
print(f"streaming overlap: {speed:.2f}x modeled wall-clock win, "
      f"objective bit-exact: {same}")
