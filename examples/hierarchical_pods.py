"""Hierarchical pod topology — dense intra-pod + int8 inter-pod rounds.

The beyond-paper deployment the ROADMAP calls "hierarchical compression":
8 clients in 2 pods of 4. Every communication round first averages
parameters *inside* each pod over the fast ICI link (dense — the link is
cheap), then runs a compressed (int8 error-feedback) round *between* pods
over the slow WAN. The engine's ``Hierarchical`` topology composes the two
``repro.comm`` reducers and prices each hop with its own α–β
``NetworkModel`` — ICI calibrated against launch/mesh.py's ICI_BW, WAN at
the TrainConfig default (5 ms, 1 Gbit/s).

The run compares flat-dense / flat-int8 / hierarchical on the same
STL-SGD^sc schedule and prints the per-hop modeled comm time for each,
then executes the same hierarchical config through the pjit-style
``StagewiseDriver`` — whose sync step emits the *real* two-level round
(``build_sync_step(hierarchical=True)``, see docs/topologies.md) — and
asserts the driver's executed byte ledger agrees with the modeled
``Hierarchical`` tree totals bit-exactly.

    PYTHONPATH=src python examples/hierarchical_pods.py [--driver]

``--driver`` skips the (slower) simulator comparison and runs only the
driver section — the CI smoke path for the hierarchical driver.
"""
import itertools
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core import simulate
from repro.core.stl_sgd import StagewiseDriver, driver_state, \
    make_client_sgd_step
from repro.data import make_binary_classification, partition_iid
from repro.engine import topology_for
from repro.models import logreg

N_CLIENTS, N_PODS = 8, 2
DRIVER_ONLY = "--driver" in sys.argv

x, y = make_binary_classification(n=4096, d=64, seed=0)
lam = 1e-3
data = {k: jnp.asarray(v) for k, v in partition_iid(x, y, N_CLIENTS).items()}
xj, yj = jnp.asarray(x), jnp.asarray(y)
loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
p0 = logreg.init_params(None, 64)

# near-exact optimum for the gap
p = p0
gd = jax.jit(lambda p: jax.tree.map(lambda a, g: a - 2.0 * g, p,
                                    jax.grad(eval_fn)(p)))
for _ in range(4000):
    p = gd(p)
fstar = float(eval_fn(p))

CONFIGS = [
    ("flat dense", dict(topology="star", reducer="dense")),
    ("flat int8", dict(topology="star", reducer="int8")),
    ("hier dense+int8", dict(topology="hier", reducer="dense",
                             inter_reducer="int8", n_pods=N_PODS)),
]

print(f"f* = {fstar:.6f}; STL-SGD^sc, {N_CLIENTS} clients"
      f" ({N_PODS} pods of {N_CLIENTS // N_PODS})\n")
if not DRIVER_ONLY:
    for name, kw in CONFIGS:
        cfg = TrainConfig(algo="stl_sc", eta1=0.5, T1=256, k1=8.0,
                          n_stages=8, iid=True, batch_per_client=32, seed=0,
                          **kw)
        hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8)
        summ = topology_for(cfg).summary(p0, N_CLIENTS, hist[-1].round)
        gap = hist[-1].value - fstar
        print(f"{name:16s} rounds={summ['rounds']:4d} "
              f"bytes={summ['total_bytes']:9d} "
              f"modeled_comm={summ['total_time_s']:7.3f}s final_gap={gap:.2e}")
        for hop in summ["hops"]:
            print(f"  └ {hop['hop']:10s} [{hop['reducer']:5s}] "
                  f"α={hop['latency_s']:.0e}s "
                  f"β⁻¹={hop['bandwidth_gbps']:.0f}Gbps "
                  f"bytes/round={hop['bytes_per_round']:6d} "
                  f"hop_time={hop['total_time_s']:.4f}s")

    print("\nThe hierarchical round keeps the dense average where bandwidth")
    print("is free (intra-pod ICI) and compresses only the WAN hop —")
    print("composing the paper's axis (fewer rounds via stagewise k_s) with")
    print("cheaper rounds on the links that actually cost something.")

# --- driver section: the same two-level round, executed by the pjit driver
#
# The StagewiseDriver's sync step now EMITS the hierarchical round
# (dense intra-pod reduce + int8-EF inter-pod hop — engine.Hierarchical's
# reduce, one shared code path with the simulator above), and the engine
# prices the run through the same Hierarchical topology. Executed and
# modeled bytes therefore must agree bit-exactly — asserted below.

print(f"\n--- StagewiseDriver, topology=hier (2-level sync round) ---")
dcfg = TrainConfig(algo="stl_sc", eta1=0.5, T1=64, k1=8.0, n_stages=4,
                   iid=True, batch_per_client=32, seed=0, topology="hier",
                   reducer="dense", inter_reducer="int8", n_pods=N_PODS)

train_step = make_client_sgd_step(loss_fn, data, batch=32)
sync_step = LS.build_sync_step("dense", hierarchical=True, n_pods=N_PODS,
                               inter_reducer="int8")
drv = StagewiseDriver(dcfg, jax.jit(train_step), jax.jit(sync_step))
ds = drv.run(driver_state(p0, N_CLIENTS),
             itertools.repeat(None))  # train_step samples via rng

consensus = jax.tree.map(lambda x: x[0], ds.state["params"])
gap = float(eval_fn(consensus)) - fstar
topo = topology_for(dcfg)
modeled = topo.round_bytes(p0, N_CLIENTS) * ds.rounds_total
print(f"driver hier     rounds={ds.rounds_total:4d} "
      f"bytes={ds.comm_bytes_total:9d} "
      f"modeled_comm={ds.comm_time_s:7.3f}s final_gap={gap:.2e}")
for l in ds.leaf_ledger:
    print(f"  └ {l['hop']:10s} leaf {l['path']:9s} bytes={l['bytes']:8d} "
          f"time={l['time_s']:.4f}s")
assert ds.comm_bytes_total == modeled, (ds.comm_bytes_total, modeled)
assert sum(l["bytes"] for l in ds.leaf_ledger) == ds.comm_bytes_total
print("\nmodeled-vs-executed byte agreement: OK "
      f"({ds.comm_bytes_total} bytes over {ds.rounds_total} two-level "
      "rounds; ledger == Hierarchical tree totals bit-exactly)")
