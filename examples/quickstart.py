"""Quickstart: STL-SGD in 60 lines.

Trains L2-regularized logistic regression (the paper's §5.1 problem) with
8 simulated clients, comparing SyncSGD / Local SGD / STL-SGD^sc on
communication rounds — the paper's headline claim, on your CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import simulate
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg

N_CLIENTS = 8

# --- problem: strongly convex logistic regression -------------------------
x, y = make_binary_classification(n=8192, d=64, seed=0)
lam = 1e-3
data = {k: jnp.asarray(v) for k, v in partition_iid(x, y, N_CLIENTS).items()}
xj, yj = jnp.asarray(x), jnp.asarray(y)
loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
params0 = logreg.init_params(None, 64)

# --- near-exact optimum for the gap --------------------------------------
p = params0
gd = jax.jit(lambda p: jax.tree.map(lambda a, g: a - 2.0 * g, p,
                                    jax.grad(eval_fn)(p)))
for _ in range(4000):
    p = gd(p)
fstar = float(eval_fn(p))
print(f"f* = {fstar:.6f}")

# --- run the three algorithms ---------------------------------------------
TARGET = 1e-4
for algo, kw in [
    ("sync", dict(k1=1.0, n_stages=24)),
    ("local", dict(k1=16.0, n_stages=24)),          # Alg. 1, fixed k
    ("stl_sc", dict(k1=8.0, n_stages=12)),          # Alg. 2: k doubles/stage
]:
    cfg = TrainConfig(algo=algo, eta1=0.5, T1=512, iid=True,
                      batch_per_client=32, seed=0, **kw)
    hist = simulate.run(loss_fn, params0, data, cfg, eval_fn, eval_every=8,
                        max_rounds=10000, target=fstar + TARGET,
                        lr_alpha=1e-3 if algo in ("sync", "local") else 0.0)
    rounds = simulate.rounds_to_target(hist, fstar + TARGET)
    print(f"{algo:8s} communication rounds to gap<{TARGET}: {rounds} "
          f"(final gap {hist[-1].value - fstar:.2e})")

print("\nSTL-SGD^sc reaches the target with the fewest communication rounds —")
print("the stagewise k-growth (k1, 2k1, 4k1, ...) is exactly Algorithm 2.")
