"""Continuous-batching serving example: ServeEngine under open-loop load.

Serves the gemma2-family smoke model (sliding-window + global alternating
attention, logit softcaps) through ``repro.serve``: Poisson arrivals join
a fixed pool of KV-cache slots at decode-step boundaries and retire
without draining the batch. Each slot's token stream is bit-exact with
running that request alone through ``core.serving.greedy_decode`` — the
example checks one request against the reference at the end.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.serving import greedy_decode
from repro.models import transformer as TF
from repro.serve import (SchedulerConfig, ServeEngine, TrafficConfig,
                         generate_requests)

cfg = get_arch("gemma2-27b", smoke=True)
params = TF.init_params(jax.random.key(0), cfg)

sched = SchedulerConfig(n_slots=4, max_seq_len=96)
engine = ServeEngine(cfg, params, scheduler=sched)
capacity = sched.n_slots / engine.decode_step_s
print(f"{cfg.name}: {sched.n_slots} slots, modeled decode step "
      f"{engine.decode_step_s:.2e}s ({capacity:.0f} tok/s capacity; "
      f"window ring-buffers hold {cfg.attention.window} slots)")

tcfg = TrafficConfig(process="poisson", rate_rps=0.5 * capacity / 24,
                     n_requests=12, mean_prompt_len=16, max_prompt_len=32,
                     mean_out_len=8, max_out_len=16, seed=0)
requests = generate_requests(tcfg, cfg.vocab_size)
report = engine.run(requests)

print(f"served {len(report.completed)}/{len(requests)} requests in "
      f"{report.n_steps} decode steps "
      f"(mean occupancy {report.mean_occupancy:.2f}/{sched.n_slots})")
print(f"modeled {report.modeled_tok_s:.0f} tok/s over "
      f"{report.makespan_s:.2e}s makespan | measured "
      f"{report.measured_tok_s:.0f} tok/s over "
      f"{report.measured_wall_s:.2f}s host wall")
for name, s in report.latency_summary().items():
    print(f"  {name:22s} p50={s['p50']:.2e} p95={s['p95']:.2e} "
          f"p99={s['p99']:.2e}")
print("generations (first 8 ids each):")
for rec in report.records[:4]:
    print(f"  req{rec.id} (slot {rec.slot}): {rec.tokens[:8]}")

# continuous batching never changes what one request decodes to
rec = report.records[0]
req = requests[0]
ref = greedy_decode(params, cfg, jnp.asarray(req.prompt[None, :]),
                    req.n_out, sched.max_seq_len)
assert rec.tokens == np.asarray(ref)[0].tolist(), "batching changed tokens"
print("req0 bit-exact with per-request greedy_decode ✓")
