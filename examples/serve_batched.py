"""Batched serving example: prefill + decode with ring-buffer KV cache.

Serves the gemma2-family smoke model (sliding-window + global alternating
attention, logit softcaps) with batched requests — the decode path the
decode_32k / long_500k dry-run shapes compile for the production mesh.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.serving import build_prefill_step, build_serve_step
from repro.models import transformer as TF

cfg = get_arch("gemma2-27b", smoke=True)
params = TF.init_params(jax.random.key(0), cfg)

B, P, G = 8, 96, 48  # batch, prompt, generate
rng = np.random.RandomState(0)
prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, P)), jnp.int32)

cache = TF.init_cache(cfg, B, P + G)
prefill = jax.jit(build_prefill_step(cfg))
step = jax.jit(build_serve_step(cfg))

t0 = time.time()
logits, cache = prefill(params, cache, prompts)
tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
jax.block_until_ready(tok)
print(f"prefill {B}×{P} tokens: {time.time()-t0:.2f}s "
      f"(window ring-buffers: local layers hold {cfg.attention.window} slots)")

out = [tok]
t0 = time.time()
for _ in range(G - 1):
    logits, cache = step(params, cache, tok)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
gen = jnp.concatenate(out, axis=1)
print(f"decoded {G} tokens × {B} seqs in {dt:.2f}s "
      f"({B * (G - 1) / dt:.1f} tok/s aggregate)")
print("generations (first 12 ids each):")
for i in range(min(B, 4)):
    print(f"  seq{i}: {np.asarray(gen[i, :12]).tolist()}")
assert int(cache["pos"]) == P + G - 1
