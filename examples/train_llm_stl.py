"""End-to-end driver: train a ~100M-param qwen3-family LM with STL-SGD for a
few hundred steps on CPU (deliverable b's end-to-end example).

Uses the real distributed step builders (the same ones the 256/512-chip
dry-run compiles), 4 clients on the host mesh, stagewise η↓ / k↑ schedule.

    PYTHONPATH=src python examples/train_llm_stl.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import AttentionConfig, TrainConfig
from repro.core import local_sgd as LS
from repro.core.stl_sgd import StagewiseDriver
from repro.launch.mesh import make_host_mesh
from repro.launch.train import synthetic_batches

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--clients", type=int, default=4)
ap.add_argument("--hundred-m", action="store_true",
                help="full ~100M config (TPU-scale; minutes/step on 1 CPU core)")
args = ap.parse_args()

if args.hundred_m:
    # ~100M params: 8 layers, d=512, vocab 8k (qwen3 family: qk_norm GQA)
    cfg = get_arch("qwen3-14b", smoke=True).replace(
        name="qwen3-100m", n_layers=8, d_model=512, d_ff=1536, vocab_size=8192,
        attention=AttentionConfig(kind="gqa", n_heads=8, n_kv_heads=4,
                                  head_dim=64, qk_norm=True))
    B, S = 2, 256
else:
    # CPU-scale stand-in of the same family (same code path; the dry-run
    # proves the full configs compile for the production mesh)
    cfg = get_arch("qwen3-14b", smoke=True).replace(
        name="qwen3-mini", n_layers=4, d_model=256, d_ff=768, vocab_size=4096,
        attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2,
                                  head_dim=64, qk_norm=True))
    B, S = 2, 128

mesh = make_host_mesh(1, 1)
C = args.clients
state = LS.init_state(jax.random.key(0), cfg, C)
n_params = sum(p.size for p in jax.tree.leaves(state["params"])) // C
print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  clients={C}")

train_local, sync_step, _ = LS.build_train_steps(
    cfg, mesh, client_axis="data", momentum=0.9)
tcfg = TrainConfig(algo="stl_sc", eta1=0.3, k1=4, T1=48, n_stages=4,
                   iid=True, momentum=0.9)
driver = StagewiseDriver(tcfg, jax.jit(train_local), jax.jit(sync_step))

batches = synthetic_batches(cfg, C, B, S, seed=0)
t0 = time.time()
ds = driver.run(state, batches, max_iters=args.steps)
dt = time.time() - t0
print(f"\n{ds.iters_total} iters / {ds.rounds_total} comm rounds "
      f"in {dt:.0f}s ({ds.iters_total * C * B * S / dt:.0f} tok/s)")
print("loss by stage:", [f"s{r.stage}:k={r.k}:{r.mean_loss:.3f}"
                         for r in ds.results])
if args.steps >= 150:
    assert ds.results[-1].mean_loss < ds.results[0].mean_loss, "loss must fall"
print("communication rounds saved vs SyncSGD at same iters: "
      f"{ds.iters_total - ds.rounds_total} "
      f"({ds.iters_total / max(ds.rounds_total, 1):.1f}x fewer)")
