"""Flat-npz pytree checkpointing with step/stage metadata.

Layout: <dir>/step_<n>.npz holding flattened leaves keyed by path string plus
a json metadata entry (stage index, schedule state, rng). Restores into the
same tree structure (template-driven), so dtype/shape drift is caught loudly.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8): widen losslessly
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree, meta: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:010d}.npz")
    flat = _flatten(tree)
    flat["__meta__"] = np.frombuffer(
        json.dumps(meta or {}).encode(), dtype=np.uint8).copy()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)  # atomic publish
    return path


def load_checkpoint(directory: str, template, step: Optional[int] = None
                    ) -> Tuple[Any, dict]:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}.npz")
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf_t in flat:
            key = jax.tree_util.keystr(p)
            arr = z[key]
            if hasattr(leaf_t, "shape") and tuple(arr.shape) != tuple(leaf_t.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != {leaf_t.shape}")
            if hasattr(leaf_t, "dtype") and arr.dtype != leaf_t.dtype:
                arr = arr.astype(leaf_t.dtype)  # cast back (bf16 widened on save)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
    return tree, meta


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None
