# Pluggable communication subsystem: how a parameter-averaging round moves
# bytes. Reducers compress/decompress client messages (with error-feedback
# residual state); cost.py prices each round with an alpha-beta network model.
from repro.comm.cost import (
    NetworkModel,
    comm_summary,
    comm_summary_for,
    dense_bytes,
    link_model,
    round_bytes,
    round_time,
)
from repro.comm.reducer import (
    DenseMean,
    QuantizedMean,
    Reducer,
    StalenessWeightedMean,
    TopKMean,
    get_reducer,
    reduce_streaming,
    supports_leaf_bytes,
)

__all__ = [
    "DenseMean",
    "NetworkModel",
    "QuantizedMean",
    "Reducer",
    "StalenessWeightedMean",
    "TopKMean",
    "comm_summary",
    "comm_summary_for",
    "dense_bytes",
    "get_reducer",
    "link_model",
    "reduce_streaming",
    "round_bytes",
    "round_time",
    "supports_leaf_bytes",
]
