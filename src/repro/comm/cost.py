"""alpha-beta network cost model for communication rounds.

A round costs ``alpha + bytes / bandwidth``: a fixed latency term (link
setup, stragglers, barrier) plus a serialization term. This is the classic
LogP-style model; with it every run reports *modeled comm-time* next to the
comm-round counts of Tables 1-3, so "fewer rounds" (stagewise k_s) and
"cheaper rounds" (compressed reducers) land in one comparable number.

Byte accounting (star / parameter-server topology, the paper's setting):
  uplink    = n_clients x reducer.message_bytes(template)   (compressed)
  downlink  = n_clients x dense model bytes                 (server broadcast)
Downlink is excluded by default (broadcast is cheap multicast in most
deployments and identical across reducers); set ``count_downlink=True`` to
include it.

Defaults model a 1 Gbit/s WAN with 5 ms round latency — override per run
via TrainConfig.comm_latency_s / comm_bandwidth_gbps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class NetworkModel:
    latency_s: float = 5e-3          # alpha: fixed per-round cost
    bandwidth_gbps: float = 1.0      # beta^-1: link bandwidth, Gbit/s
    count_downlink: bool = False

    @property
    def bandwidth_Bps(self) -> float:
        return self.bandwidth_gbps * 1e9 / 8.0


def dense_bytes(template) -> int:
    """Uncompressed payload of one model replica (the downlink broadcast)."""
    size = lambda l: int(jnp.prod(jnp.asarray(l.shape))) if l.shape else 1
    return sum(size(l) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(template))


def round_bytes(reducer, template, n_clients: int,
                model: NetworkModel | None = None) -> int:
    """Modeled bytes moved in one communication round."""
    model = model or NetworkModel()
    up = n_clients * reducer.message_bytes(template)
    if model.count_downlink:
        up += n_clients * dense_bytes(template)
    return up


def round_time(model: NetworkModel, n_bytes: int) -> float:
    """alpha-beta cost of one round carrying n_bytes."""
    return model.latency_s + n_bytes / model.bandwidth_Bps


def comm_summary_for(cfg, template, n_clients: int, n_rounds: int) -> dict:
    """comm_summary resolved from a TrainConfig's reducer/comm_* fields.

    The one place benchmarks and examples turn a finished run's config +
    round count into the modeled comm report.
    """
    from repro.comm.reducer import get_reducer

    return comm_summary(
        get_reducer(cfg.reducer, quant_bits=cfg.quant_bits,
                    topk_frac=cfg.topk_frac),
        template, n_clients, n_rounds,
        NetworkModel(latency_s=cfg.comm_latency_s,
                     bandwidth_gbps=cfg.comm_bandwidth_gbps))


def comm_summary(reducer, template, n_clients: int, n_rounds: int,
                 model: NetworkModel | None = None) -> dict:
    """Full comm-cost report for a finished run."""
    model = model or NetworkModel()
    per_round = round_bytes(reducer, template, n_clients, model)
    t_round = round_time(model, per_round)
    return {
        "reducer": reducer.name,
        "rounds": int(n_rounds),
        "bytes_per_round": int(per_round),
        "total_bytes": int(per_round) * int(n_rounds),
        "round_time_s": t_round,
        "total_time_s": t_round * int(n_rounds),
        "latency_s": model.latency_s,
        "bandwidth_gbps": model.bandwidth_gbps,
    }
