"""alpha-beta network cost model for communication rounds.

A round costs ``alpha + bytes / bandwidth``: a fixed latency term (link
setup, stragglers, barrier) plus a serialization term. This is the classic
LogP-style model; with it every run reports *modeled comm-time* next to the
comm-round counts of Tables 1-3, so "fewer rounds" (stagewise k_s) and
"cheaper rounds" (compressed reducers) land in one comparable number.

Byte accounting (star / parameter-server topology, the paper's setting):
  uplink    = n_clients x reducer.message_bytes(template)   (compressed)
  downlink  = n_clients x dense model bytes                 (server broadcast)
Downlink is excluded by default (broadcast is cheap multicast in most
deployments and identical across reducers); set ``count_downlink=True`` to
include it.

Defaults model a 1 Gbit/s WAN with 5 ms round latency — override per run
via TrainConfig.comm_latency_s / comm_bandwidth_gbps, or pick a calibrated
preset with ``link_model("ici" | "dcn" | "wan")``: the ici/dcn numbers are
derived from the v5e interconnect constants in ``launch/mesh.py``
(ICI_BW/DCN_BW), so modeled comm time in benchmarks lines up with the
roofline's hardware model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class NetworkModel:
    """One α–β link: ``latency_s`` is the fixed per-message cost α in
    seconds (setup, barrier), ``bandwidth_gbps`` the serialization rate
    β⁻¹ in Gbit/s. ``count_downlink=True`` additionally bills the dense
    server broadcast (excluded by default: multicast, reducer-independent).
    All times this model produces are modeled seconds, all payloads bytes.
    """

    latency_s: float = 5e-3          # alpha: fixed per-round cost
    bandwidth_gbps: float = 1.0      # beta^-1: link bandwidth, Gbit/s
    count_downlink: bool = False

    @property
    def bandwidth_Bps(self) -> float:
        """Link bandwidth in bytes/second (Gbit/s × 1e9 / 8)."""
        return self.bandwidth_gbps * 1e9 / 8.0

    def time(self, n_bytes: float) -> float:
        """α–β cost in modeled seconds of moving ``n_bytes`` bytes."""
        return self.latency_s + n_bytes / self.bandwidth_Bps


def link_model(name: str) -> NetworkModel:
    """Calibrated per-hop presets (α, β) for the hierarchical topology.

    Bandwidths come from the v5e constants in ``launch/mesh.py`` — ICI_BW
    (50 GB/s/link) and DCN_BW (6.25 GB/s/host) — converted to Gbit/s;
    latencies are order-of-magnitude link setup costs (µs-scale ICI,
    tens of µs DCN, ms-scale WAN barrier).
    """
    from repro.launch.mesh import DCN_BW, ICI_BW

    presets = {
        "ici": NetworkModel(latency_s=1e-6, bandwidth_gbps=ICI_BW * 8 / 1e9),
        "dcn": NetworkModel(latency_s=25e-6, bandwidth_gbps=DCN_BW * 8 / 1e9),
        "wan": NetworkModel(latency_s=5e-3, bandwidth_gbps=1.0),
    }
    try:
        return presets[name]
    except KeyError:
        raise ValueError(f"unknown link preset: {name!r} "
                         f"(expected {sorted(presets)})") from None


def dense_bytes(template) -> int:
    """Uncompressed payload of one model replica (the downlink broadcast).

    Static shape arithmetic only — no traced arrays (leaf shapes are always
    concrete, even for ShapeDtypeStructs inside jit).
    """
    return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(template))


def round_bytes(reducer, template, n_clients: int,
                model: NetworkModel | None = None) -> int:
    """Modeled payload bytes one communication round moves: ``n_clients``
    compressed uplink messages (``reducer.message_bytes``, bytes), plus
    — only when the model counts it — the dense downlink broadcast."""
    model = model or NetworkModel()
    up = n_clients * reducer.message_bytes(template)
    if model.count_downlink:
        up += n_clients * dense_bytes(template)
    return up


def round_time(model: NetworkModel, n_bytes: int) -> float:
    """Serial α–β cost in modeled seconds of one round carrying
    ``n_bytes`` bytes: one latency α plus serialization at β."""
    return model.latency_s + n_bytes / model.bandwidth_Bps


def comm_summary_for(cfg, template, n_clients: int, n_rounds: int) -> dict:
    """comm_summary resolved from a TrainConfig's reducer/comm_*/topology
    fields.

    The one place benchmarks and examples turn a finished run's config +
    round count into the modeled comm report. Star configs (the default)
    produce the flat single-link report; hierarchical configs report the
    per-hop breakdown (with a composite "reducer" name) so the summary
    always prices the topology the run actually used.
    """
    from repro.engine.engine import topology_for
    from repro.engine.topology import Star

    topo = topology_for(cfg)
    if isinstance(topo, Star):
        return comm_summary(topo.reducer, template, n_clients, n_rounds,
                            topo.network)
    summ = topo.summary(template, n_clients, n_rounds)
    summ["reducer"] = "+".join(h["reducer"] for h in summ["hops"])
    return summ


def comm_summary(reducer, template, n_clients: int, n_rounds: int,
                 model: NetworkModel | None = None) -> dict:
    """Full comm-cost report for a finished run.

    Also publishes the report's totals as ``comm.summary_*`` gauges in the
    ``repro.obs`` metrics registry (labelled by reducer), so a benchmark's
    final report lands next to the per-stage counters the engine emits.
    """
    from repro.obs import metrics as obs_metrics

    model = model or NetworkModel()
    per_round = round_bytes(reducer, template, n_clients, model)
    t_round = round_time(model, per_round)
    m = obs_metrics.registry()
    m.gauge("comm.summary_bytes", unit="B",
            help="total modeled payload bytes of the summarized run").set(
                int(per_round) * int(n_rounds), reducer=reducer.name)
    m.gauge("comm.summary_time_s", unit="s",
            help="total modeled serial α–β seconds of the summarized "
                 "run").set(t_round * int(n_rounds), reducer=reducer.name)
    return {
        "reducer": reducer.name,
        "rounds": int(n_rounds),
        "bytes_per_round": int(per_round),
        "total_bytes": int(per_round) * int(n_rounds),
        "round_time_s": t_round,
        "total_time_s": t_round * int(n_rounds),
        "latency_s": model.latency_s,
        "bandwidth_gbps": model.bandwidth_gbps,
    }
