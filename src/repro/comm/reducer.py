"""Reducer protocol — pluggable compression for the communication round.

A *reducer* owns Algorithm 1 line 5 (the parameter average). Compressed
reducers follow the standard error-feedback template over *round deltas*:
every client starts the round at the shared consensus ``ref``; after its k
local steps it uploads

    m_i = C((x_i - ref) + e_i)          (compress delta + carried residual)
    e_i' = (x_i - ref) + e_i - m_i      (what the compressor dropped)

and the server forms the next consensus ``ref' = ref + mean_i m_i``. Deltas
have far smaller dynamic range than raw parameters, so the same bit budget
buys much less distortion, and the residual state e_i makes the scheme
convergent (EF-SGD-style) even for biased compressors like top-k. This
composes the paper's axis (fewer rounds, stagewise k_s) with the orthogonal
axis (cheaper rounds, fewer bytes per round).

All reducers are pure pytree->pytree functions of (stacked replicas,
state, rng), safe inside jit / lax.scan — state keeps a stable tree
structure across calls.

Implementations
  DenseMean     — identity compression; bit-exact with tree_mean_leading.
  QuantizedMean — int8 (or narrower) symmetric stochastic-rounding delta
                  quantization per (client, leaf); Pallas-fused kernels in
                  repro.kernels.quantize (impl="interpret"/"pallas") or the
                  jnp oracle (impl="xla", default — fastest on CPU).
  TopKMean      — magnitude top-k delta sparsification per (client, leaf);
                  messages are (value, index) pairs.

``message_bytes(template)`` reports the compressed uplink payload one client
sends per round — the quantity comm.cost prices. ``template`` is a
single-replica pytree (arrays or ShapeDtypeStructs).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.quantize import ops as Q
from repro.obs import metrics as obs_metrics
from repro.utils.tree import tree_mean_leading

_EPS = 1e-12


def _leaf_elems(leaf) -> int:
    size = 1
    for d in leaf.shape:
        size *= d
    return size


class Reducer:
    """Base protocol. Subclasses override the tree-level ``reduce()`` and the
    per-leaf byte accounting (``leaf_message_bytes``); the per-leaf reduce
    protocol (``split_state`` / ``reduce_leaf`` / ``join_state``) is what the
    streaming execution paths (``engine.StreamingStar``,
    ``local_sgd.build_sync_step(streaming=True)``) drive — leaf by leaf, same
    numerics as the tree-level call."""

    name = "base"

    def init_state(self, stacked):
        """Residual/reference state for the stacked (N, ...) replica tree.

        Call at run start, when all replicas are identical (post-broadcast).
        """
        return None

    def reduce(self, stacked, state, rng):
        """(stacked replicas, state, rng) -> (consensus tree, new state).

        The consensus tree has the leading client axis removed; callers
        rebroadcast it (tree_broadcast_leading) to continue local training.
        """
        raise NotImplementedError

    # -- per-leaf protocol (streaming reduce) -------------------------------

    def split_state(self, state, treedef):
        """Split the reducer state into one per-leaf slice.

        ``treedef`` is the stacked replica tree's structure; the returned
        list is index-aligned with ``jax.tree.flatten(stacked)[0]``. The
        base (stateless) implementation yields ``None`` per leaf.
        """
        return [None] * treedef.num_leaves

    def join_state(self, leaf_states, treedef) -> "object":
        """Inverse of ``split_state``: rebuild the tree-level state."""
        return None

    def reduce_leaf(self, x, leaf_state, rng):
        """Reduce ONE stacked (N, ...) leaf -> (consensus leaf, new state).

        Leaves are independent, so calling this per leaf — in any order,
        with the same per-leaf rng the tree-level ``reduce`` would fold —
        is bit-exact with one tree-level call. This is the unit the
        streaming paths interleave with per-leaf compute.
        """
        raise NotImplementedError

    # -- byte accounting ----------------------------------------------------

    def leaf_message_bytes(self, template) -> list:
        """Per-leaf compressed uplink payload, in bytes, one client sends
        per round — index-aligned with ``jax.tree.leaves(template)``. The
        per-leaf comm ledger (``engine.Topology.leaf_costs``) and the
        streaming upload schedule (``runtime.StreamingSchedule``) consume
        this; ``message_bytes`` is its sum, so the two views reconcile
        bit-exactly by construction.
        """
        raise NotImplementedError

    def message_bytes(self, template) -> int:
        """Total compressed uplink bytes one client sends per round."""
        return sum(self.leaf_message_bytes(template))

    def __repr__(self):
        return f"{type(self).__name__}()"


@dataclass(frozen=True, repr=False)
class DenseMean(Reducer):
    """Uncompressed average — the pre-comm-subsystem behavior, bit-exact."""

    name = "dense"

    def reduce(self, stacked, state, rng):
        return tree_mean_leading(stacked), state

    # split_state / join_state: inherited stateless base implementations

    def reduce_leaf(self, x, leaf_state, rng):
        """Mean over the leading client axis of one leaf — the exact op
        ``tree_mean_leading`` applies per leaf, so per-leaf streaming is
        bit-exact with the tree-level average."""
        return jnp.mean(x, axis=0), leaf_state

    def leaf_message_bytes(self, template) -> list:
        """Raw leaf payloads: elements × itemsize bytes per leaf."""
        return [_leaf_elems(l) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(template)]


class _DeltaReducer(Reducer):
    """Shared error-feedback-over-deltas machinery for compressed reducers.

    Subclasses implement ``_compress(y, rng) -> (deq, mean)`` on a (N, M)
    f32 block of per-client deltas: ``deq`` is each client's decompressed
    message (N, M), ``mean`` its average (M,).
    """

    error_feedback: bool = True

    def init_state(self, stacked):
        return {
            # ref: the shared consensus every client started the round from
            "ref": jax.tree.map(lambda x: x[0].astype(jnp.float32), stacked),
            # res: per-client residual the compressor dropped so far
            "res": jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), stacked),
        }

    def split_state(self, state, treedef):
        refs = treedef.flatten_up_to(state["ref"])
        res = treedef.flatten_up_to(state["res"])
        return [{"ref": r, "res": e} for r, e in zip(refs, res)]

    def join_state(self, leaf_states, treedef):
        return {"ref": treedef.unflatten([s["ref"] for s in leaf_states]),
                "res": treedef.unflatten([s["res"] for s in leaf_states])}

    def reduce_leaf(self, x, leaf_state, rng):
        """One leaf's EF round: compress (delta + residual), average, carry
        the compression error forward. Same op order as the historical
        tree-level loop body, so per-leaf streaming is bit-exact."""
        r, e = leaf_state["ref"], leaf_state["res"]
        n = x.shape[0]
        y = (x.astype(jnp.float32).reshape(n, -1)
             - r.reshape(1, -1) + e.reshape(n, -1))
        deq, mean_delta = self._compress(y, rng)
        consensus = r.reshape(-1) + mean_delta
        drop = (y - deq) if self.error_feedback else jnp.zeros_like(y)
        return (consensus.reshape(r.shape).astype(x.dtype),
                {"ref": consensus.reshape(r.shape),
                 "res": drop.reshape(e.shape)})

    def reduce(self, stacked, state, rng):
        leaves, treedef = jax.tree.flatten(stacked)
        states = self.split_state(state, treedef)
        means, new_states = [], []
        for i, (x, st) in enumerate(zip(leaves, states)):
            consensus, ns = self.reduce_leaf(x, st, jax.random.fold_in(rng, i))
            means.append(consensus)
            new_states.append(ns)
        return treedef.unflatten(means), self.join_state(new_states, treedef)


@dataclass(frozen=True, repr=False)
class QuantizedMean(_DeltaReducer):
    """Symmetric stochastic-rounding delta quantization with error feedback.

    Per (client, leaf): scale = max|delta|, codes = SR(delta/scale * qmax)
    in ``bits``-bit signed range (stored int8). Stochastic rounding keeps the
    quantizer unbiased; the residual carries the lattice error forward.
    ``error_feedback=False`` gives the naive quantizer (for ablations — it
    stalls at the quantization noise floor where EF keeps converging).
    """

    bits: int = 8
    impl: str = "xla"  # "xla" | "interpret" | "pallas"
    error_feedback: bool = True
    # stochastic=False rounds to nearest (u = 0.5 constant): biased, only
    # safe together with error feedback — used by the EF ablation tests.
    stochastic: bool = True

    @property
    def name(self):
        return f"int{self.bits}" + ("" if self.error_feedback else "-noef")

    def _compress(self, y, rng):
        scales = jnp.maximum(jnp.max(jnp.abs(y), axis=1), _EPS)
        if self.stochastic:
            rbits = jax.random.bits(rng, y.shape, jnp.uint32)
        else:
            rbits = jnp.full(y.shape, 1 << 31, jnp.uint32)  # u = 0.5
        # the per-leaf kernel path: one self-contained encode/decode per
        # leaf, so streaming rounds can pipeline it against other leaves
        q = Q.encode_leaf(y, rbits, scales, bits=self.bits, impl=self.impl)
        deq, mean = Q.decode_mean_leaf(q, scales, bits=self.bits,
                                       impl=self.impl)
        return deq, mean

    def leaf_message_bytes(self, template) -> list:
        # bits-wide codes (packed) + one f32 scale per leaf
        return [-(-_leaf_elems(l) * self.bits // 8) + 4
                for l in jax.tree.leaves(template)]


@dataclass(frozen=True, repr=False)
class TopKMean(_DeltaReducer):
    """Magnitude top-k delta sparsification with error feedback.

    Per (client, leaf): keep the k = max(1, round(frac * size)) largest-
    magnitude delta entries; the rest accumulate into the residual.
    Messages are (f32 value, i32 index) pairs.
    """

    frac: float = 0.1
    error_feedback: bool = True

    @property
    def name(self):
        return f"top{self.frac:g}" + ("" if self.error_feedback else "-noef")

    def _k(self, size: int) -> int:
        return max(1, min(size, int(round(self.frac * size))))

    def _compress(self, y, rng):
        n = y.shape[0]
        k = self._k(y.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(y), k)
        vals = jnp.take_along_axis(y, idx, axis=1)
        deq = jnp.zeros_like(y).at[jnp.arange(n)[:, None], idx].set(vals)
        return deq, jnp.sum(deq, axis=0) * (1.0 / n)

    def leaf_message_bytes(self, template) -> list:
        # (f32 value + i32 index) per kept entry
        return [8 * self._k(_leaf_elems(l))
                for l in jax.tree.leaves(template)]


@dataclass(frozen=True, repr=False)
class StalenessWeightedMean(_DeltaReducer):
    """Merge-on-arrival reducer for asynchronous rounds (repro.runtime).

    The barrier-free analogue of the delta reducers above: each client still
    uploads a (optionally int8-quantized, reusing the kernels behind
    ``QuantizedMean``) error-feedback-corrected round delta, but the server
    applies messages *as they arrive* instead of averaging a full cohort:

        server' = server + w(τ)/N · deq(C(Δ_i + e_i))
        w(τ)    = (1 + τ)^(-decay)

    where the staleness τ counts *server cycles beyond the natural pipeline
    lag*: in a steady barrier-free rotation every upload races the other
    N−1 clients' merges, so the runtime reports
    τ = max(0, merges_since_pull − (N−1)) / N — a client keeping pace
    merges at full weight (async ≈ sync in the homogeneous limit), while a
    straggler whose delta raced S extra full cycles is decayed by
    (1+S)^(−decay).

    The synchronous Reducer protocol (``reduce`` over a stacked cohort) is
    also implemented — all clients at τ=0 — so the topology/cost plumbing
    prices it like any other reducer; the per-message half (``encode`` /
    ``merge``) is what the event runtime drives.
    """

    decay: float = 0.5
    compress: str = "dense"   # "dense" | "int" (bits-wide quantization)
    bits: int = 8
    impl: str = "xla"
    error_feedback: bool = True

    @property
    def name(self):
        tag = "" if self.compress == "dense" else f"-int{self.bits}"
        return f"staleness{tag}"

    def weight(self, staleness: float) -> float:
        """Merge weight for a message that is ``staleness`` cycles late."""
        return (1.0 + max(0.0, float(staleness))) ** (-self.decay)

    def _compress(self, y, rng):
        if self.compress == "dense":
            return y, jnp.mean(y, axis=0)
        return QuantizedMean(bits=self.bits, impl=self.impl)._compress(y, rng)

    # -- per-message async protocol (driven by repro.runtime) ---------------

    def client_residual(self, template):
        """Fresh per-client error-feedback residual (f32 zeros tree)."""
        return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32),
                            template)

    def encode(self, delta, residual, rng):
        """One client's upload: compress (Δ + e).

        Returns (payload, residual') where ``payload`` is the decompressed
        f32 delta tree the server will apply and ``residual'`` carries what
        the compressor dropped (zeros when error feedback is off).
        """
        leaves, treedef = jax.tree.flatten(delta)
        res = treedef.flatten_up_to(residual)
        payloads, new_res = [], []
        for i, (d, e) in enumerate(zip(leaves, res)):
            y = (d.astype(jnp.float32) + e).reshape(1, -1)
            deq, _ = self._compress(y, jax.random.fold_in(rng, i))
            p = deq.reshape(d.shape)
            payloads.append(p)
            new_res.append((y.reshape(e.shape) - p) if self.error_feedback
                           else jnp.zeros_like(e))
        # encode runs eagerly once per upload (never inside jit), so this
        # is a safe per-message metric emission point
        m = obs_metrics.registry()
        m.counter("comm.messages", unit="messages",
                  help="async client uploads encoded").inc(
                      reducer=self.name)
        m.counter("comm.message_bytes", unit="B",
                  help="compressed payload bytes of async uploads").inc(
                      sum(self.leaf_message_bytes(delta)),
                      reducer=self.name)
        return treedef.unflatten(payloads), treedef.unflatten(new_res)

    def merge(self, server, payload, staleness: float, n_clients: int):
        """Apply one arrived message to the server model."""
        w = self.weight(staleness) / float(n_clients)
        obs_metrics.registry().histogram(
            "comm.merge_weight", unit="weight",
            help="staleness-decayed merge weights w(τ)/N applied").observe(
                w, reducer=self.name)
        return jax.tree.map(lambda s, p: s + w * p.astype(s.dtype),
                            server, payload)

    def leaf_message_bytes(self, template) -> list:
        if self.compress == "dense":
            return [_leaf_elems(l) * 4 for l in jax.tree.leaves(template)]
        return [-(-_leaf_elems(l) * self.bits // 8) + 4
                for l in jax.tree.leaves(template)]


def supports_leaf_bytes(reducer: Reducer) -> bool:
    """Explicit capability probe for the per-leaf byte protocol.

    True iff ``reducer`` *overrides* ``leaf_message_bytes`` — the callers
    that need per-leaf payloads (``engine.Topology.leaf_costs``, the event
    runtime's streaming schedules) branch on this probe instead of calling
    the method under ``except NotImplementedError``: a bug raised *inside*
    an implemented per-leaf method must propagate, never silently degrade
    to monolithic pricing.
    """
    return type(reducer).leaf_message_bytes is not Reducer.leaf_message_bytes


def reduce_streaming(reducer: Reducer, stacked, state, rng, *,
                     broadcast_n: int | None = None):
    """One streaming round: reduce the stacked replica tree leaf by leaf.

    The single copy of the per-leaf round structure every streaming
    execution path shares (``engine.StreamingStar.reduce``,
    ``local_sgd.build_sync_step(streaming=True)``): leaves are processed
    in *reverse-layer order* — the order they finish their last local
    step under backprop — and each leaf folds the same per-leaf rng the
    tree-level ``reducer.reduce`` folds (``fold_in(rng, leaf_index)``),
    so the result is bit-exact with the blocking round. Returns
    ``(consensus tree, new state)`` like ``Reducer.reduce``.

    ``broadcast_n`` additionally emits the *per-leaf downlink*: each leaf
    is rebroadcast to ``(broadcast_n, ...)`` replicas immediately after
    its reduce, inside the same per-leaf loop, so under jit every leaf's
    reduce → broadcast pair is one self-contained data-independent unit
    XLA may overlap with the remaining leaves — the execution mirror of
    ``runtime.StreamingSchedule.broadcast_events``. The returned tree then
    carries the leading replica axis (numerics are bit-exact with
    broadcasting the blocking consensus after the fact).
    """
    leaves, treedef = jax.tree.flatten(stacked)
    states = reducer.split_state(state, treedef)
    out = [None] * len(leaves)
    new = [None] * len(leaves)
    for i in reversed(range(len(leaves))):
        out[i], new[i] = reducer.reduce_leaf(
            leaves[i], states[i], jax.random.fold_in(rng, i))
        if broadcast_n is not None:
            out[i] = jnp.broadcast_to(out[i][None],
                                      (broadcast_n,) + out[i].shape)
    return treedef.unflatten(out), reducer.join_state(new, treedef)


def get_reducer(spec, *, quant_bits: int = 8, topk_frac: float = 0.1,
                impl: str = "xla", staleness_decay: float = 0.5) -> Reducer:
    """Resolve a reducer from a config string (or pass a Reducer through).

    Accepted specs: "dense" | "int8" / "quant" (quant_bits-wide) |
    "int<b>" (explicit width) | "topk" (topk_frac) |
    "staleness" / "staleness-int<b>" (async merge-on-arrival weights).
    """
    if isinstance(spec, Reducer):
        return spec
    if spec in (None, "dense", "mean"):
        return DenseMean()
    if spec in ("quant", "int8", "quantized"):
        b = 8 if spec == "int8" else quant_bits
        return QuantizedMean(bits=b, impl=impl)
    if spec == "staleness":
        return StalenessWeightedMean(decay=staleness_decay)
    if spec.startswith("staleness-int"):
        return StalenessWeightedMean(decay=staleness_decay, compress="int",
                                     bits=int(spec[len("staleness-int"):]),
                                     impl=impl)
    if spec.startswith("int"):
        return QuantizedMean(bits=int(spec[3:]), impl=impl)
    if spec == "topk":
        return TopKMean(frac=topk_frac)
    raise ValueError(f"unknown reducer spec: {spec!r}")
