"""Architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``FULL`` (the exact assigned config) and ``SMOKE``
(reduced variant: ≤2-3 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from repro.configs.base import (
    ArchConfig,
    AttentionConfig,
    MoEConfig,
    SSMConfig,
    RGLRUConfig,
    ShapeConfig,
    TrainConfig,
    SHAPES,
)

from repro.configs import (
    minicpm3_4b,
    musicgen_medium,
    qwen3_14b,
    deepseek_v2_236b,
    internvl2_2b,
    gemma3_12b,
    phi35_moe,
    gemma2_27b,
    recurrentgemma_2b,
    mamba2_2_7b,
)

ARCHS = {
    "minicpm3-4b": minicpm3_4b,
    "musicgen-medium": musicgen_medium,
    "qwen3-14b": qwen3_14b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "internvl2-2b": internvl2_2b,
    "gemma3-12b": gemma3_12b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "gemma2-27b": gemma2_27b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "mamba2-2.7b": mamba2_2_7b,
}

# Archs whose base attention is quadratic-full: long_500k runs their
# sliding-window VARIANT (ring-buffer KV, window=8192). See DESIGN.md §5.
SWA_VARIANT_FOR_LONG = {
    "minicpm3-4b",
    "musicgen-medium",
    "qwen3-14b",
    "deepseek-v2-236b",
    "internvl2-2b",
    "phi3.5-moe-42b-a6.6b",
}
LONG_WINDOW = 8192


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    mod = ARCHS[name]
    return mod.SMOKE if smoke else mod.FULL


def arch_for_shape(name: str, shape: str, smoke: bool = False) -> ArchConfig:
    """Resolve the arch config to use for a given input shape.

    long_500k on full-attention archs swaps in the sliding-window variant so
    decode state stays O(window) instead of O(seq_len).
    """
    cfg = get_arch(name, smoke=smoke)
    if shape == "long_500k" and name in SWA_VARIANT_FOR_LONG:
        att = cfg.attention
        assert att is not None
        cfg = cfg.replace(
            name=cfg.name + "+swa",
            attention=AttentionConfig(
                **{**att.__dict__, "window": LONG_WINDOW},
            ),
            block_pattern=("L",),
        )
    return cfg


__all__ = [
    "ArchConfig",
    "AttentionConfig",
    "MoEConfig",
    "SSMConfig",
    "RGLRUConfig",
    "ShapeConfig",
    "TrainConfig",
    "SHAPES",
    "ARCHS",
    "get_arch",
    "arch_for_shape",
    "SWA_VARIANT_FOR_LONG",
    "LONG_WINDOW",
]
