"""Architecture / run configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. Model code in
``repro.models`` consumes only this schema; nothing else about an arch is
hard-coded anywhere.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"  # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    qk_norm: bool = False            # qwen3
    logit_softcap: Optional[float] = None  # gemma2 (50.0)
    rope_theta: float = 10000.0
    # Sliding-window: applied to layers marked "L" in ArchConfig.block_pattern.
    window: Optional[int] = None
    # MLA (deepseek-v2 / minicpm3)
    q_lora_rank: Optional[int] = None
    kv_lora_rank: Optional[int] = None
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0        # deepseek-v2: 2 shared experts
    d_expert: int = 1536     # per-expert hidden dim
    aux_coef: float = 0.01   # load-balance auxiliary loss weight
    capacity_factor: float = 1.25  # expert buffer slack; large => dropless
    # dense (non-MoE) first layers, e.g. deepseek-v2 replaces layer 0 MoE w/ dense MLP
    n_dense_layers: int = 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    # RecurrentGemma recurrent block (arXiv:2402.19427)
    lru_width: Optional[int] = None  # default: d_model
    d_conv: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str = "arch"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""       # citation (paper / model card)
    n_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32000
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # Per-layer block kinds, cycled over n_layers:
    #   "G" global attention, "L" sliding-window attention,
    #   "M" mamba2/SSD block, "R" RG-LRU recurrent block.
    block_pattern: Tuple[str, ...] = ("G",)
    tie_embeddings: bool = True
    final_softcap: Optional[float] = None  # gemma2 (30.0)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # int8 symmetric per-(position, head) KV-cache quantization (decode paths)
    kv_quant: bool = False
    # Megatron-SP-style sequence parallelism: residual-stream activations are
    # sharded over `model` on the sequence dim between blocks, turning each
    # activation all-reduce into reduce-scatter + all-gather (≈½ the bytes).
    seq_parallel: bool = False
    # Modality frontend stub: None | "vision" | "audio". When set, the model
    # additionally consumes precomputed frame/patch embeddings (stub carve-out).
    frontend: Optional[str] = None
    n_frontend_tokens: int = 256   # patches / audio frames prepended to the text tokens
    frontend_dim: int = 1024       # raw embedding dim before the projector

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand block_pattern cyclically to n_layers entries."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters for one training run (paper's Alg. 1–3 knobs)."""
    algo: str = "stl_sc"  # sync | lb | crpsgd | local | stl_sc | stl_nc1 | stl_nc2
    eta1: float = 0.1       # initial learning rate η₁
    k1: float = 8.0         # initial communication period k₁
    T1: int = 100           # first-stage length T₁
    n_stages: int = 6       # S
    iid: bool = True        # IID vs Non-IID k-growth rule (2 vs √2)
    gamma_inv: float = 0.0  # 1/γ for the prox term in STL-SGD^nc (Alg. 3); 0 = none
    momentum: float = 0.0
    weight_decay: float = 0.0
    batch_per_client: int = 32
    # baselines
    batch_growth: float = 1.1  # CR-PSGD ρ
    max_batch: int = 512
    seed: int = 0
    # communication round (repro.comm): reducer spec + α–β network model.
    # "dense" is bit-exact Alg. 1; "int8"/"int<b>" = stochastic-rounding
    # quantization (quant_bits wide for "quant"), "topk" = magnitude top-k.
    reducer: str = "dense"
    quant_bits: int = 8          # width for reducer="quant"/"int<b>"
    topk_frac: float = 0.1       # kept fraction for reducer="topk"
    comm_latency_s: float = 5e-3      # α: fixed per-round latency
    comm_bandwidth_gbps: float = 1.0  # β⁻¹: link bandwidth
    # communication topology (repro.engine): "star" is the paper's flat
    # parameter-server setting; "hier" splits clients into n_pods pods —
    # ``reducer`` runs intra-pod over calibrated ICI, ``inter_reducer``
    # inter-pod over the comm_latency_s/comm_bandwidth_gbps WAN link.
    # Honored by both front-ends: the vmapped simulator reduces through
    # engine.Hierarchical, and the StagewiseDriver executes the same
    # two-level round via a local_sgd.build_sync_step(hierarchical=True,
    # n_pods=..., inter_reducer=...) sync step (whose tags must agree with
    # these fields — the driver refuses mismatches so the ledger always
    # prices the round the collectives execute). n_pods=1 degenerates to
    # the flat star round bit-exactly (no inter-pod link exists).
    topology: str = "star"
    n_pods: int = 2
    inter_reducer: str = "int8"
    # discrete-event runtime (repro.runtime): heterogeneous clients + async.
    # async_mode wraps cfg.algo in an AsyncPeriod policy — clients upload
    # after k local steps without barriering and the server merges each
    # message on arrival with weight (1 + staleness)^(-staleness_decay).
    # Heterogeneity knobs feed the event clock: per-local-step compute time,
    # straggler cohort (frac of clients slowed by slowdown×), lognormal
    # per-client compute/network jitter, and per-upload dropout probability.
    async_mode: bool = False
    staleness_decay: float = 0.5
    base_step_time_s: float = 1e-3
    straggler_frac: float = 0.0
    straggler_slowdown: float = 1.0
    compute_jitter: float = 0.0
    dropout_rate: float = 0.0
    # upload schedule (repro.runtime): how a client's round-end message
    # meets the event clock. "blocking" ships one monolithic message after
    # compute_done; "streaming" starts each leaf's upload as soon as its
    # last local step completes (reverse-layer order), overlapping the
    # remaining compute — modeled time only, trajectories are bit-exact
    # across schedules. The execution-side analogue is topology="streaming"
    # (engine.StreamingStar: the pjit driver's per-leaf reduce).
    # "streaming-uplink" restores the uplink-only overlap (blocking WAN hop
    # + monolithic broadcast) — the comparator the full streaming round's
    # downlink/WAN overlap is measured against.
    upload_schedule: str = "blocking"
    # bill the dense server→client broadcast as its own downlink hop
    # (comm.NetworkModel.count_downlink). Off by default (multicast,
    # reducer-independent — see docs/cost_model.md); when on, the blocking
    # schedule ships it monolithically after the merge while the streaming
    # schedule ships leaf l as soon as the server finishes reducing it.
    count_downlink: bool = False
