"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6. [arXiv:2405.04434]

60L d_model=5120 128H (GQA kv=128) d_ff(expert)=1536 vocab=102400, MoE 160e top-6.
The first layer is a dense SwiGLU MLP (d_ff=12288) per the DeepSeek-V2 paper;
``ArchConfig.d_ff`` holds the dense-layer dim, ``moe.d_expert`` the per-expert dim
(=1536 as in the assignment line).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

FULL = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=60,
    d_model=5120,
    d_ff=12288,
    vocab_size=102400,
    attention=AttentionConfig(
        kind="mla",
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=10000.0,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        n_shared=2,
        d_expert=1536,
        aux_coef=0.003,
        n_dense_layers=1,
    ),
    block_pattern=("G",),
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="deepseek-v2-236b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="mla",
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        q_lora_rank=128,
        kv_lora_rank=64,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    moe=MoEConfig(
        n_experts=4, top_k=2, n_shared=1, d_expert=128, aux_coef=0.003, n_dense_layers=1, capacity_factor=64.0
    ),
)
