"""gemma2-27b [dense] — alternating local/global attention, logit softcap. [arXiv:2408.00118]

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, window 4096 on local
layers, attention logit softcap 50.0, final logit softcap 30.0.
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=46,
    d_model=4608,
    d_ff=36864,
    vocab_size=256000,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        logit_softcap=50.0,
        window=4096,
        rope_theta=10000.0,
    ),
    block_pattern=("L", "G"),
    final_softcap=30.0,
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="gemma2-27b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="gqa", n_heads=4, n_kv_heads=2, head_dim=64, logit_softcap=50.0, window=64
    ),
)
