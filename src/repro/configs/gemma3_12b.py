"""gemma3-12b [dense] — 5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt family]

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144. Layer pattern is five
sliding-window (1024) layers followed by one global layer. Native local
attention qualifies this arch for long_500k decode.
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab_size=262144,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        qk_norm=True,
        window=1024,
        rope_theta=1000000.0,
    ),
    block_pattern=("L", "L", "L", "L", "L", "G"),
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="gemma3-12b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="gqa", n_heads=4, n_kv_heads=2, head_dim=64, qk_norm=True, window=64
    ),
    block_pattern=("L", "G"),
)
