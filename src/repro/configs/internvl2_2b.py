"""internvl2-2b [vlm] — InternViT + InternLM2. [arXiv:2404.16821]

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The InternViT vision
encoder is a STUB: ``input_specs()`` provides precomputed patch embeddings
(batch, n_patches, frontend_dim); the projector + InternLM2-style decoder is
implemented in full.
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        rope_theta=1000000.0,
    ),
    block_pattern=("G",),
    frontend="vision",
    n_frontend_tokens=256,
    frontend_dim=1024,
)

SMOKE = FULL.replace(
    name="internvl2-2b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=64),
    n_frontend_tokens=16,
    frontend_dim=96,
)
