"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128, expand=2 (d_inner=5120),
head_dim=64 → 80 SSD heads. The SSD forward uses the chunked matmul (duality)
form — the TPU/MXU-native adaptation of the paper's GPU kernel.
"""
from repro.configs.base import ArchConfig, SSMConfig

FULL = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50280,
    attention=None,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
    block_pattern=("M",),
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="mamba2-2.7b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=0,
    vocab_size=512,
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=64),
)
