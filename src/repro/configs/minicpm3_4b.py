"""minicpm3-4b [dense] — MLA attention. [hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448, Multi-head Latent
Attention with q_lora=768 / kv_lora=256 (per the MiniCPM3 model card).
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    attention=AttentionConfig(
        kind="mla",
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        rope_theta=10000.0,
    ),
    block_pattern=("G",),
)

SMOKE = FULL.replace(
    name="minicpm3-4b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="mla",
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        q_lora_rank=96,
        kv_lora_rank=64,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
)
