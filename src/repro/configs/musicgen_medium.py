"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048. The EnCodec
(mel-spectrogram + conv codec) frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, n_frames, frontend_dim); the
transformer decoder over codebook tokens is implemented in full.
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
    ),
    block_pattern=("G",),
    frontend="audio",
    n_frontend_tokens=256,
    frontend_dim=768,
)

SMOKE = FULL.replace(
    name="musicgen-medium-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=64),
    n_frontend_tokens=16,
    frontend_dim=96,
)
