"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct]

32L d_model=4096 32H (GQA kv=8) d_ff(expert)=6400 vocab=32064, MoE 16e top-2.
All layers are MoE (no shared experts, no dense layers).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        rope_theta=10000.0,
    ),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=6400, aux_coef=0.01),
    block_pattern=("G",),
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="phi3.5-moe-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=64),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128, aux_coef=0.01, capacity_factor=64.0),
)
