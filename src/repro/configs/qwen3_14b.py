"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import ArchConfig, AttentionConfig

FULL = ArchConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151936,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        qk_norm=True,
        rope_theta=1000000.0,
    ),
    block_pattern=("G",),
    tie_embeddings=False,
)

SMOKE = FULL.replace(
    name="qwen3-14b-smoke",
    n_layers=2,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="gqa", n_heads=4, n_kv_heads=2, head_dim=64, qk_norm=True
    ),
)
