"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 LRU. [arXiv:2402.19427]

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000, pattern
(R, R, L) — two RG-LRU recurrent blocks then one sliding-window (2048)
attention block. Constant-size recurrent state makes long_500k native.
"""
from repro.configs.base import ArchConfig, AttentionConfig, RGLRUConfig

FULL = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    d_ff=7680,
    vocab_size=256000,
    attention=AttentionConfig(
        kind="gqa",
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        window=2048,
        rope_theta=10000.0,
    ),
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    block_pattern=("R", "R", "L"),
    tie_embeddings=True,
)

SMOKE = FULL.replace(
    name="recurrentgemma-2b-smoke",
    n_layers=3,
    d_model=256,
    d_ff=512,
    vocab_size=512,
    attention=AttentionConfig(
        kind="gqa", n_heads=4, n_kv_heads=1, head_dim=64, window=64
    ),
    rglru=RGLRUConfig(lru_width=256, d_conv=4),
)
