# The paper's primary contribution: Local SGD with stagewise communication
# period (STL-SGD), as schedules + distributed step builders + drivers.
from repro.core import schedules, simulate, local_sgd, stl_sgd, baselines, prox, serving

__all__ = ["schedules", "simulate", "local_sgd", "stl_sgd", "baselines", "prox", "serving"]
