"""Baseline drivers the paper compares against (§5): SyncSGD, LB-SGD, CR-PSGD.

All three are degenerate Algorithms in the ``repro.engine`` registry — the
``EveryStep`` sync policy (k = 1) with different ``LocalUpdate`` batch rules
— so the baseline implementations share every line of distributed machinery
with STL-SGD. CR-PSGD's growing batch is realised by the data pipeline
(``crpsgd_batch_sizes``), keeping the step function shape-stable per size.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import TrainConfig
from repro.core.stl_sgd import StagewiseDriver


def sync_sgd_driver(tcfg: TrainConfig, train_step, sync_step) -> StagewiseDriver:
    return StagewiseDriver(_with_algo(tcfg, "sync"), train_step, sync_step)


def lb_sgd_driver(tcfg: TrainConfig, train_step, sync_step) -> StagewiseDriver:
    return StagewiseDriver(_with_algo(tcfg, "lb"), train_step, sync_step)


def crpsgd_batch_sizes(b0: int, growth: float, n_steps: int, max_batch: int,
                       quantum: int = 8) -> List[int]:
    """CR-PSGD batch schedule, quantised to multiples of ``quantum`` so the
    number of distinct compiled step shapes stays small."""
    sizes = []
    b = float(b0)
    for _ in range(n_steps):
        q = min(max_batch, int(b / quantum + 0.5) * quantum or quantum)
        sizes.append(max(quantum, q))
        b = min(float(max_batch), b * growth)
    return sizes


def _with_algo(tcfg: TrainConfig, algo: str) -> TrainConfig:
    import dataclasses

    return dataclasses.replace(tcfg, algo=algo)
