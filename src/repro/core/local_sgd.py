"""Distributed Local-SGD step builders (pjit + client replicas on a mesh axis).

The paper's clients map to a mesh axis (DESIGN.md §2/§4):

  * ``train_step_local`` — every client takes one SGD step on its own replica.
    Parameters carry a leading client axis sharded on ``client_axis``; the
    step is ``jax.vmap(per_client_step, spmd_axis_name=client_axis)`` so XLA
    emits **zero collectives on the client axis** (tensor-parallel collectives
    on ``model`` remain). Executed k_s times per round.

  * ``sync_step`` — Algorithm 1 line 5: the parameter-averaging round. One
    all-reduce of params (+ optimizer moments) over the client axis.

  * two-level sync (``client_axis=("pod", "data")`` + ``inter_reducer``):
    the paper's clients live on the pod×data grid and every sync runs the
    real hierarchical round — a dense intra-pod reduce over ``data``
    followed by a (typically compressed) inter-pod hop over ``pod`` — via
    ``build_sync_step(hierarchical=True)``, the same ``engine.Hierarchical``
    reduce the simulator executes (see docs/topologies.md).

  * hierarchical pod-client mode (``client_axis="pod"``): grads are
    additionally all-reduced over ``data`` *inside* the local step (SyncSGD
    within a pod over fast ICI), while the stagewise schedule governs only
    the expensive inter-pod parameter average. This is the beyond-paper
    deployment mode.

All builders return *lowerable* jitted callables — the multi-pod dry-run
compiles exactly these.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import get_reducer
from repro.comm.reducer import DenseMean, reduce_streaming
from repro.configs.base import ArchConfig
from repro.models import transformer as TF
from repro.optim import make_optimizer
from repro.sharding import param_specs
from repro.sharding.rules import cache_specs
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ArchConfig, batch):
    """Next-token CE. batch: {"tokens","labels": (B,S)} [+ "frontend"]."""
    logits, aux = TF.forward(params, cfg, batch["tokens"], batch.get("frontend"))
    S = batch["labels"].shape[1]
    logits = logits[:, -S:, :]  # drop frontend positions
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def batch_spec(cfg: ArchConfig, client_axis: Optional[str], extra_data_axis: bool):
    """PartitionSpec tree for the training batch.

    Leading batch dim carries the client axis and (hierarchical mode) the
    intra-pod data axis.
    """
    axes = []
    if client_axis:
        # multi-axis client grids (("pod", "data") on a multi-pod mesh)
        # shard the one leading client dim over all their mesh axes
        if isinstance(client_axis, (tuple, list)):
            axes.extend(client_axis)
        else:
            axes.append(client_axis)
    if extra_data_axis:
        axes.append("data")
    lead = tuple(axes) if axes else None
    spec = {"tokens": P(lead, None), "labels": P(lead, None)}
    if cfg.frontend:
        spec["frontend"] = P(lead, None, None)
    return spec


def build_sync_step(reducer=None, *, base_seed: int = 0,
                    streaming: bool = False, hierarchical: bool = False,
                    n_pods: int = 2, inter_reducer="int8"):
    """Reducer-aware Algorithm 1 line 5: the parameter-averaging round.

    Returns ``sync_step(state) -> state``. With the default DenseMean this is
    exactly the historical dense average (and leaves the state tree
    untouched). With a compressed reducer, each client's message is
    compressed with error feedback; the residual state rides in
    ``state["comm"]`` (created on first sync), and the reducer rng derives
    from ``state["step"]`` so the step stays a pure jittable function.
    Optimizer moments are always dense-averaged — they never cross the
    network in a real deployment (the average mirrors Alg. 1's replica
    consensus, not a transmitted payload).

    ``streaming=True`` emits the *per-leaf* round (``engine.StreamingStar``
    semantics): one independent ``reduce_leaf`` per parameter leaf, in
    reverse-layer order — the order leaves finish their last local step
    under backprop. Numerics are bit-exact with the blocking round (each
    leaf folds the same per-leaf rng), but the reduce is expressed as
    per-leaf data-independent ops, so when the step runs under jit XLA's
    scheduler is free to interleave leaf l's reduce with the remaining
    leaves' compute instead of waiting on one whole-tree collective. The
    consensus broadcast is emitted per leaf inside the same loop (the
    downlink mirror of the per-leaf uplink). Composes with
    ``hierarchical=True``: the two-level round then runs per leaf too
    (``Hierarchical(streaming=True)`` — intra-pod reduce feeding the
    inter-pod reduce leaf by leaf).

    ``hierarchical=True`` emits the *two-level* round
    (``engine.Hierarchical`` semantics, see ``docs/topologies.md``): an
    intra-pod reduce with ``reducer`` (dense by default — the hop rides
    cheap ICI) followed by an inter-pod reduce of the ``n_pods`` pod means
    with ``inter_reducer`` (int8-EF by default — the hop crosses the WAN).
    Clients are pods' contiguous slices of the leading client axis, the
    layout a ``(pod, data, model)`` mesh shards pod-major, so under pjit
    the intra hop's collectives stay on the ``data`` axis and the inter
    hop's on the ``pod`` axis — the driver's collectives structurally
    match what the ``Hierarchical`` cost model prices. The round *is*
    ``Hierarchical.reduce`` (one shared code path), so it is bit-exact
    with the simulator's hierarchical trace on the same rng; per-hop
    error-feedback residuals ride in ``state["comm"]``. Degenerate cases
    keep the flat contract exactly: ``n_pods=1`` (no inter-pod link
    exists) and dense∘dense (the two-level mean collapses to the flat
    mean) both produce the flat round bit-exactly.
    """
    reducer = get_reducer(reducer)
    dense = isinstance(reducer, DenseMean)

    if hierarchical:
        if n_pods < 1:
            raise ValueError(f"n_pods must be >= 1, got {n_pods}")
        if n_pods > 1:
            return _build_two_level_sync_step(reducer, n_pods, inter_reducer,
                                              base_seed, streaming)
        # n_pods == 1: a single pod has no inter-pod hop to cross — the
        # round degenerates to the flat round with the intra reducer
        # (streaming or blocking; bit-exact with the flat path by
        # construction; the inter reducer is unused because no WAN link
        # exists)

    def sync_step(state):
        n = jax.tree.leaves(state["params"])[0].shape[0]
        opt = tree_broadcast_leading(tree_mean_leading(state["opt"]), n)
        rng = jax.random.fold_in(jax.random.key(base_seed), state["step"])
        if dense and not streaming:
            params = tree_broadcast_leading(
                tree_mean_leading(state["params"]), n)
            out = dict(state, params=params, opt=opt)
        elif dense:
            # streaming dense round: per-leaf mean + per-leaf rebroadcast
            # inside the same reversed loop (state tree untouched, like
            # the blocking dense round; rng unused) — leaf l's reduce and
            # downlink broadcast form one data-independent unit under jit
            params, _ = reduce_streaming(reducer, state["params"], None,
                                         rng, broadcast_n=n)
            out = dict(state, params=params, opt=opt)
        else:
            comm = state.get("comm")
            if comm is None:
                comm = reducer.init_state(state["params"])
            if streaming:
                params, comm = reduce_streaming(reducer, state["params"],
                                                comm, rng, broadcast_n=n)
            else:
                consensus, comm = reducer.reduce(state["params"], comm, rng)
                params = tree_broadcast_leading(consensus, n)
            out = dict(state, params=params, opt=opt, comm=comm)
        return out

    # tag the step with its reducer (and round structure) so
    # StagewiseDriver's comm accounting can't drift from what the round
    # actually transmits
    sync_step.reducer = reducer
    sync_step.streaming = streaming
    sync_step.hierarchical = False
    return sync_step


def _build_two_level_sync_step(intra, n_pods: int, inter_reducer,
                               base_seed: int, streaming: bool = False):
    """The hierarchical (n_pods > 1) round behind ``build_sync_step``.

    One ``engine.Hierarchical.reduce`` per sync — the same code path the
    vmapped simulator executes for ``topology="hier"`` — with the per-hop
    reducer state riding in ``state["comm"]`` (created on first sync, like
    the flat compressed round). The dense∘dense configuration keeps the
    state tree untouched: ``Hierarchical`` collapses it to the flat mean
    and its reducer state is inert, so the round matches the flat dense
    round exactly, key set included.

    ``streaming=True`` executes the same round per leaf
    (``Hierarchical(streaming=True)``): leaf l's intra-pod reduce feeds
    its inter-pod reduce immediately, in reverse-layer order, so under
    jit the WAN collective of late leaves is free to overlap the
    intra-pod reduction of the early ones. Bit-exact with the blocking
    two-level round (same per-leaf rng folds on both hops).
    """
    from repro.engine.topology import Hierarchical

    inter = get_reducer(inter_reducer)
    topo = Hierarchical(n_pods=n_pods, intra=intra, inter=inter,
                        streaming=streaming)

    def sync_step(state):
        n = jax.tree.leaves(state["params"])[0].shape[0]
        if n % n_pods:
            # concrete at trace time — same contract as Hierarchical
            raise ValueError(
                f"{n} client replicas not divisible into {n_pods} pods")
        opt = tree_broadcast_leading(tree_mean_leading(state["opt"]), n)
        rng = jax.random.fold_in(jax.random.key(base_seed), state["step"])
        if topo.all_dense:
            consensus, _ = topo.reduce(state["params"], None, rng)
            out = dict(state,
                       params=tree_broadcast_leading(consensus, n), opt=opt)
        else:
            comm = state.get("comm")
            if comm is None:
                comm = topo.init_state(state["params"])
            consensus, comm = topo.reduce(state["params"], comm, rng)
            out = dict(state, params=tree_broadcast_leading(consensus, n),
                       opt=opt, comm=comm)
        return out

    # tags: the driver prices the topology the round actually executes
    sync_step.reducer = intra
    sync_step.streaming = streaming
    sync_step.hierarchical = True
    sync_step.n_pods = n_pods
    sync_step.inter_reducer = inter
    return sync_step


def sync_step_tags(sync_step) -> dict:
    """The comm tags ``build_sync_step`` stamped on a round, read through
    any stack of wrappers that chain ``__wrapped__`` (``jax.jit``,
    ``functools.wraps`` decorators like ``obs.ProfileSession.wrap``).

    Returns ``{"reducer", "streaming", "hierarchical"}`` plus
    ``{"n_pods", "inter_reducer"}`` for two-level rounds; absent tags come
    back ``None``/``False``. ``StagewiseDriver`` reads its comm accounting
    *and* its trace-span attributes from here, so the priced ledger and
    the exported timeline can't drift from the round the step executes.
    """
    def tag(name, default=None):
        fn, v = sync_step, None
        for _ in range(8):   # walk the full wrapper chain (cycle-safe)
            if fn is None:
                break
            v = getattr(fn, name, None)
            if v is not None:
                break
            fn = getattr(fn, "__wrapped__", None)
        return default if v is None else v

    tags = {"reducer": tag("reducer"),
            "streaming": bool(tag("streaming", False)),
            "hierarchical": bool(tag("hierarchical", False))}
    if tags["hierarchical"]:
        tags["n_pods"] = tag("n_pods")
        tags["inter_reducer"] = tag("inter_reducer")
    return tags


def build_train_steps(cfg: ArchConfig, mesh, *, client_axis: str = "data",
                      optimizer: str = "sgd", momentum: float = 0.0,
                      weight_decay: float = 0.0,
                      loss_fn: Optional[Callable] = None,
                      microbatch: int = 1,
                      sync_grads: bool = False,
                      reducer=None,
                      streaming: bool = False,
                      inter_reducer=None,
                      donate: bool = True):
    """Returns (train_step_local, sync_step, specs) for the given mesh.

    train_step_local(state, batch, eta) -> (state, metrics)
        state = {"params": (C, ...), "opt": (C, ...), "step": scalar}
    sync_step(state) -> state   (client-axis parameter average; built by
        ``build_sync_step(reducer, streaming=streaming)`` — pass ``reducer``
        for a compressed round, default dense; ``streaming=True`` for the
        per-leaf reduce XLA can overlap with compute)

    ``microbatch`` > 1 splits each client's batch into that many
    gradient-accumulation slices (scan), dividing activation memory.
    In hierarchical mode (client_axis="pod") the per-client gradient is
    additionally pmean'd over "data" inside the local step.

    ``inter_reducer`` (with a client axis spanning "pod", e.g.
    ``client_axis=("pod", "data")`` on a multi-pod mesh) selects the
    *two-level* sync round: the paper's clients live on the pod×data grid
    and every sync runs a dense intra-pod reduce over ``data`` followed by
    an ``inter_reducer`` round over the ``pod`` axis (int8-EF WAN by
    default) — ``build_sync_step(hierarchical=True)`` with ``n_pods``
    taken from the mesh. ``None`` (default) keeps the historical flat
    client-axis average.
    """
    loss_fn = loss_fn or lm_loss
    hierarchical = client_axis == "pod"
    two_level = inter_reducer is not None
    if two_level:
        axes = (client_axis if isinstance(client_axis, (tuple, list))
                else (client_axis,))
        if "pod" not in axes or "pod" not in mesh.axis_names:
            raise ValueError(
                f"inter_reducer={inter_reducer!r} requests the two-level "
                f"sync round, but client_axis={client_axis!r} on a mesh "
                f"with axes {tuple(mesh.axis_names)} has no 'pod' axis to "
                f"cross — use client_axis=('pod', 'data') on a multi-pod "
                f"mesh")
        n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    opt_init, opt_update = make_optimizer(optimizer, momentum, weight_decay)

    def per_client_grad(params, batch):
        if microbatch == 1:
            return jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)

        def slice_mb(x, i):
            mb = x.shape[0] // microbatch
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            loss_acc, g_acc = carry
            mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
            loss, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, mb))(params)
            return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero),
            jnp.arange(microbatch))
        inv = 1.0 / microbatch
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def per_client_step(params, opt_state, batch, eta):
        if hierarchical:
            # batch: (data_shards, per_shard, S). SyncSGD within the pod —
            # per-shard grads (vmapped over `data`) averaged over the leading
            # axis = the intra-pod gradient all-reduce over fast ICI.
            losses, grads = jax.vmap(
                lambda b: per_client_grad(params, b),
                spmd_axis_name="data")(batch)
            grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            loss = jnp.mean(losses)
        else:
            loss, grads = per_client_grad(params, batch)
        if sync_grads:
            # SyncSGD baseline: all-reduce grads over the client axis.
            grads = jax.lax.pmean(grads, axis_name="clients")
            loss = jax.lax.pmean(loss, axis_name="clients")
        params, opt_state = opt_update(params, grads, opt_state, eta)
        return params, opt_state, loss

    vstep = jax.vmap(per_client_step, in_axes=(0, 0, 0, None),
                     out_axes=(0, 0, 0), spmd_axis_name=client_axis,
                     axis_name="clients")

    def train_step_local(state, batch, eta):
        params, opt, loss = vstep(state["params"], state["opt"], batch, eta)
        # dict(state, ...) so extra keys (e.g. a compressed sync_step's
        # "comm" error-feedback residuals) survive the local step.
        return dict(state, params=params, opt=opt, step=state["step"] + 1), {
            "loss": jnp.mean(loss)}

    sync_step = (build_sync_step(reducer, streaming=streaming,
                                 hierarchical=True, n_pods=n_pods,
                                 inter_reducer=inter_reducer)
                 if two_level else
                 build_sync_step(reducer, streaming=streaming))

    return train_step_local, sync_step, per_client_step


def state_shardings(cfg: ArchConfig, mesh, params_shape, opt_shape,
                    client_axis: str = "data"):
    """NamedShardings for the training state pytree.

    Hierarchical mode (client_axis == 'pod') additionally FSDP-shards each
    replica over the intra-pod 'data' axis.
    """
    from repro.sharding.rules import feasible_specs

    fsdp = "data" if client_axis == "pod" else None
    pspecs = feasible_specs(
        param_specs(params_shape, client_axis=client_axis, fsdp_axis=fsdp),
        params_shape, mesh)
    ospecs = {"mu": pspecs} if "mu" in opt_shape else {
        k: (pspecs if k in ("m", "v") else P()) for k in opt_shape}
    to_sh = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                      is_leaf=lambda s: isinstance(s, P))
    return {"params": to_sh(pspecs), "opt": to_sh(ospecs),
            "step": NamedSharding(mesh, P())}


def init_state(rng, cfg: ArchConfig, n_clients: int, optimizer: str = "sgd"):
    """Materialised training state with client replicas (small configs only)."""
    opt_init, _ = make_optimizer(optimizer)
    params = TF.init_params(rng, cfg)
    opt = opt_init(params)
    return {
        "params": tree_broadcast_leading(params, n_clients),
        "opt": tree_broadcast_leading(opt, n_clients),
        "step": jnp.zeros((), jnp.int32),
    }


def init_state_shape(cfg: ArchConfig, n_clients: int, optimizer: str = "sgd"):
    """Shape-only state (ShapeDtypeStructs) for the dry-run."""
    return jax.eval_shape(
        lambda k: init_state(k, cfg, n_clients, optimizer), jax.random.key(0))
