"""Regularized surrogate objective for STL-SGD^nc (Alg. 3).

At stage s the subalgorithm minimizes
    f^γ_{x_s}(x) = f(x) + (1/2γ) ||x − x_s||²
with γ⁻¹ = 2ρ > ρ, which convexifies a ρ-weakly-convex f, so Theorem 1's
convex analysis applies within each stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def prox_loss(loss_fn, gamma_inv: float):
    """Wrap ``loss_fn(params, batch)`` into f^γ with center passed at call time.

    Returns ``fn(params, batch, center)``; ``gamma_inv == 0`` disables the term
    (plain Local SGD subproblem, used by STL-SGD^sc).
    """
    if gamma_inv == 0.0:
        def fn(params, batch, center):
            return loss_fn(params, batch)
        return fn

    def fn(params, batch, center):
        base = loss_fn(params, batch)
        sq = sum(
            jnp.sum(jnp.square((p - c).astype(jnp.float32)))
            for p, c in zip(jax.tree.leaves(params), jax.tree.leaves(center))
        )
        return base + 0.5 * gamma_inv * sq

    return fn
