"""Stagewise schedules — the heart of STL-SGD (Algorithms 2 & 3).

A ``Stage`` bundles (η_s, T_s, k_s). Schedules produce stages:

  stl_sc / stl_nc1 (geometric, Alg. 2 & Alg. 3 Option 1):
      η_{s+1} = η_s / 2,   T_{s+1} = 2 T_s,
      k_{s+1} = 2 k_s (IID)   |   √2 k_s (Non-IID)

  stl_nc2 (linear, Alg. 3 Option 2):
      η_s = η_1 / s,   T_s = s T_1,
      k_s = s k_1 (IID)   |   √s k_1 (Non-IID)

  local (fixed k), sync (k = 1): single-stage degenerate schedules.

``theory_k1`` gives the paper's admissible initial period (Thm. 1/2):
      IID:     k₁ = min( 1/(6 η₁ L N),  1/(9 η₁ L) )
      Non-IID: k₁ = min( σ/√(6 η₁ L N (σ² + 4 ζ*)),  1/(9 η₁ L) )

and ``comm_rounds`` computes Σ_s T_s / k_s — the quantity Tables 1–3 count.

``Stage``, ``k_growth`` and the schedule expansion now live in the
``repro.engine`` SyncPolicy layer (each policy owns its η_s/T_s/k_s rule);
this module re-exports them and keeps ``make_stages(algo, ...)`` as the
name-based convenience wrapper over the algorithm registry.
"""
from __future__ import annotations

import math
from typing import List

from repro.engine.policy import Stage, k_growth  # noqa: F401  (re-export)


def theory_k1(eta1: float, L: float, N: int, sigma: float = 1.0,
              zeta: float = 0.0, iid: bool = True) -> float:
    """Paper's initial communication period (Theorem 1 / 2 / 3)."""
    if iid:
        return min(1.0 / (6.0 * eta1 * L * N), 1.0 / (9.0 * eta1 * L))
    denom = math.sqrt(6.0 * eta1 * L * N * (sigma ** 2 + 4.0 * zeta))
    return min(sigma / denom, 1.0 / (9.0 * eta1 * L))


def make_stages(algo: str, eta1: float, T1: int, k1: float, n_stages: int,
                iid: bool = True) -> List[Stage]:
    """Expand a registered algorithm's SyncPolicy into concrete stages."""
    from repro.engine.algorithm import get_algorithm

    return get_algorithm(algo).sync_policy.stages(eta1, T1, k1, n_stages, iid)


def comm_rounds(stages: List[Stage]) -> int:
    """Total communication rounds Σ_s ceil(T_s / k_s)."""
    return sum(math.ceil(st.T / st.k) for st in stages)


def total_iters(stages: List[Stage]) -> int:
    return sum(st.T for st in stages)


def min_stages_sc(N: int, f_gap0: float, eta1: float, sigma: float) -> int:
    """Theorem 2's stage-count condition: S ≥ log(N·Δ₀/(η₁σ²)) + 2."""
    val = max(N * f_gap0 / max(eta1 * sigma ** 2, 1e-30), 1.0)
    return int(math.ceil(math.log2(val))) + 2


def predicted_complexity(algo: str, N: int, T: int, iid: bool) -> float:
    """Closed-form communication-complexity orders from Table 3 (up to consts).

    Used by benchmarks/table3 to cross-check measured Σ T_s/k_s scaling.
    """
    if algo == "sync":
        return float(T)
    if algo in ("stl_sc", "stl_nc1"):
        return N * math.log(max(T, 2)) if iid else math.sqrt(N) * math.sqrt(T)
    if algo == "stl_nc2":
        return N ** 1.5 * math.sqrt(T) if iid else N ** 0.75 * T ** 0.75
    if algo == "local":
        return N ** 1.5 * math.sqrt(T) if iid else N ** 0.75 * T ** 0.75
    raise ValueError(algo)
