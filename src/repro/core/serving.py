"""Serving step builders: batched prefill + decode against sharded KV caches.

Decode shapes (decode_32k, long_500k) lower ``serve_step``: ONE new token per
sequence against a cache of seq_len (ring-buffer of window for SWA archs,
O(1) recurrent state for SSM/RG-LRU). No client axis — serving replicates
params over data/pod and shards the request batch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as TF
from repro.sharding import param_specs
from repro.sharding.rules import cache_specs


def build_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, tokens) -> (logits, cache). tokens (B,1)."""

    def serve_step(params, cache, tokens):
        logits, cache = TF.decode_step(params, cfg, tokens, cache)
        return logits, cache

    return serve_step


def build_prefill_step(cfg: ArchConfig):
    def prefill_step(params, cache, tokens, frontend=None):
        return TF.prefill(params, cfg, tokens, cache, frontend)

    return prefill_step


def serve_shardings(cfg: ArchConfig, mesh, params_shape, cache_shape,
                    data_axes=("data",)):
    pspecs = param_specs(params_shape, client_axis=None)
    cspecs = cache_specs(cache_shape, data_axes=data_axes)
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda s: isinstance(s, P))
    tok_sh = NamedSharding(mesh, P(tuple(data_axes), None))
    return to_sh(pspecs), to_sh(cspecs), tok_sh


def greedy_decode(params, cfg: ArchConfig, prompt, n_steps: int, max_len: int,
                  frontend=None):
    """Simple reference decode loop (examples / tests).

    The per-request ground truth the continuous-batching engine
    (``repro.serve``) is pinned bit-exact against. ``max_len`` sizes the KV
    cache and must cover prompt + generation (+ ``cfg.n_frontend_tokens``
    when ``frontend`` embeddings are passed — frontend archs prepend their
    patch/frame tokens, which occupy cache slots like text tokens).
    """
    B = prompt.shape[0]
    cache = TF.init_cache(cfg, B, max_len)
    logits, cache = TF.prefill(params, cfg, prompt, cache, frontend)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    step = jax.jit(lambda p, t, c: TF.decode_step(p, cfg, t, c))
    for _ in range(n_steps - 1):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
