"""N-client Local-SGD simulator — the vmapped execution backend.

This is the engine behind the paper-fidelity convergence experiments
(Figures 1–4, Tables 1–2): N client replicas live on a stacked leading axis,
local steps are vmapped (no communication), and a communication round is a
``repro.engine`` Topology reduction over the leading axis — a Star of the
configured ``repro.comm`` reducer by default (DenseMean is bit-exact
Algorithm 1 semantics), or a Hierarchical pod topology composing a dense
intra-pod average with a compressed inter-pod round.

Since the engine refactor this module is a *backend*: ``run()`` resolves
``cfg.algo`` through ``repro.engine.get_algorithm`` and hands a
``VmapSimulatorBackend`` to ``Engine.run`` — the SyncPolicy owns the stage
stream, the LocalUpdate owns the batch rule (large-batch / growing-batch
baselines included), and the same Engine drives the distributed
``StagewiseDriver``. The historical signature and the DenseMean trajectory
are preserved bit-for-bit (regression-pinned in tests/test_engine.py).

Algorithm names accepted by ``run`` are whatever the registry knows:
  sync, lb, crpsgd, local, stl_sc, stl_nc1, stl_nc2 (see repro.engine).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import get_reducer
from repro.configs.base import TrainConfig
from repro.core.prox import prox_loss
from repro.engine.engine import Engine, StageStatus
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading, tree_zeros_like

# fold_in salt deriving the reducer's rng from the round rng without
# consuming it — keeps the local-step rng stream (and thus the DenseMean
# trajectory) bit-identical to the pre-comm-subsystem dense path.
_COMM_SALT = 0x5EED


@dataclass
class Record:
    round: int      # communication rounds so far
    iteration: int  # total iterations so far
    value: float    # eval_fn(averaged params)


def _sample_batch(data, rng, batch: int):
    """data: client-local dict of arrays with leading dim n. Uniform minibatch."""
    n = jax.tree.leaves(data)[0].shape[0]
    idx = jax.random.randint(rng, (batch,), 0, n)
    return jax.tree.map(lambda a: a[idx], data)


def make_round_fn(loss_fn, *, k: int, batch: int, momentum: float,
                  lr_alpha: float, grow: float, b0: int, max_batch: int,
                  reducer=None):
    """One communication round = k vmapped local steps + 1 reduced average.

    Returned fn: (carry, rng, data, center, eta) -> carry where
    carry = (params_stacked, momentum_stacked, t_global_f32, comm_state).
    loss_fn(params, batch, center, weights) -> scalar.

    ``reducer`` (default DenseMean) owns the parameter average — any object
    with the reduce/init_state protocol works, i.e. a ``comm.Reducer`` or a
    ``engine.Topology``; its residual/error-feedback state rides in the
    carry. Momentum is always dense-averaged: it never leaves the client in
    a real deployment, the average only mirrors Alg. 1's replica-consensus
    bookkeeping.
    """
    reducer = reducer if reducer is not None else get_reducer(None)

    def batch_weights(t):
        if grow <= 1.0:
            return jnp.ones((batch,), jnp.float32) / batch
        bt = jnp.minimum(float(max_batch), float(b0) * grow ** t)
        bt = jnp.clip(jnp.round(bt), 1, batch)
        mask = (jnp.arange(batch) < bt).astype(jnp.float32)
        return mask / bt

    def round_fn(carry, rng_r, data, center, eta):
        N = jax.tree.leaves(carry[0])[0].shape[0]

        def local_step(c, rng_t):
            params, mom, t = c
            eta_t = eta / (1.0 + lr_alpha * t)
            w = batch_weights(t)

            def client(p, m, d, rng):
                b = _sample_batch(d, rng, batch)
                g = jax.grad(lambda q: loss_fn(q, b, center, w))(p)
                m2 = jax.tree.map(lambda mm, gg: momentum * mm + gg, m, g)
                p2 = jax.tree.map(lambda pp, mm: pp - eta_t * mm, p, m2)
                return p2, m2

            rngs = jax.random.split(rng_t, N)
            params, mom = jax.vmap(client)(params, mom, data, rngs)
            return (params, mom, t + 1.0), None

        params, mom, t, comm = carry
        (params, mom, t), _ = jax.lax.scan(
            local_step, (params, mom, t), jax.random.split(rng_r, k))
        consensus, comm = reducer.reduce(
            params, comm, jax.random.fold_in(rng_r, _COMM_SALT))
        params = tree_broadcast_leading(consensus, N)
        mom = tree_broadcast_leading(tree_mean_leading(mom), N)
        return (params, mom, t, comm)

    return round_fn


class VmapSimulatorBackend:
    """Engine backend: N vmapped client replicas on one host.

    Owns the chunked-scan execution of each stage (``chunk_rounds``
    communication rounds per jit call, per-round eval inside the scan), the
    (round, objective) history, and the target/max_rounds early exit.
    Compiled chunk functions are cached per (k, batch) — stages that only
    change η reuse the compilation (η is a traced operand).
    """

    def __init__(self, loss_fn: Callable, init_params, client_data,
                 eval_fn: Callable, *, eval_every: int = 1,
                 max_rounds: Optional[int] = None,
                 target: Optional[float] = None, lr_alpha: float = 0.0,
                 chunk_rounds: int = 32):
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.client_data = client_data
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self.max_rounds = max_rounds
        self.target = target
        self.lr_alpha = lr_alpha
        self.chunk_rounds = chunk_rounds

    def setup(self, engine: Engine):
        cfg = engine.cfg
        algo = engine.algorithm
        N = jax.tree.leaves(self.client_data)[0].shape[0]
        self.use_prox = algo.uses_center(cfg)
        ploss = prox_loss(self.loss_fn, algo.gamma_inv(cfg))
        self.wloss = algo.local_update.make_loss(ploss)
        self.batch = algo.local_update.round_batch(cfg)
        self.grow = algo.local_update.growth(cfg)

        self.params = tree_broadcast_leading(self.init_params, N)
        self.mom = tree_zeros_like(self.params)
        self.comm_state = engine.topology.init_state(self.params)
        self.rng = jax.random.key(cfg.seed)
        self.history: List[Record] = [
            Record(0, 0, float(self.eval_fn(self.init_params)))]
        self.rounds_done = 0
        self.iters_done = 0
        self.t_global = 0.0
        self._chunk_cache = {}
        engine.set_cost_basis(self.init_params, N)

    def _chunk_fn(self, engine: Engine, k: int, b: int):
        key = (k, b)
        if key not in self._chunk_cache:
            cfg = engine.cfg
            round_fn = make_round_fn(
                self.wloss, k=k, batch=b, momentum=cfg.momentum,
                lr_alpha=self.lr_alpha, grow=self.grow,
                b0=cfg.batch_per_client, max_batch=cfg.max_batch,
                reducer=engine.topology)
            eval_fn = self.eval_fn

            @partial(jax.jit, static_argnames=("n",))
            def chunk_fn(carry, rng_c, data, ctr, eta, n):
                def body(c, rng_r):
                    c = round_fn(c, rng_r, data, ctr, eta)
                    return c, eval_fn(tree_mean_leading(c[0]))
                return jax.lax.scan(body, carry, jax.random.split(rng_c, n))

            self._chunk_cache[key] = chunk_fn
        return self._chunk_cache[key]

    def run_stage(self, stage, engine: Engine) -> StageStatus:
        k = stage.k
        chunk_fn = self._chunk_fn(engine, k, self.batch)
        # Non-prox algorithms have no center: pass None (an empty pytree) so
        # nothing downstream can silently consume a stale parameter snapshot.
        center = tree_mean_leading(self.params) if self.use_prox else None

        status = StageStatus()
        n_rounds = -(-stage.T // k)  # ceil
        carry = (self.params, self.mom,
                 jnp.asarray(self.t_global, jnp.float32), self.comm_state)
        done_in_stage = 0
        while done_in_stage < n_rounds:
            n = min(self.chunk_rounds, n_rounds - done_in_stage)
            self.rng, sub = jax.random.split(self.rng)
            carry, vals = chunk_fn(carry, sub, self.client_data, center,
                                   stage.eta, n)
            vals = list(map(float, vals))
            hit = None
            for j, v in enumerate(vals):
                rd = self.rounds_done + j + 1
                at_target = self.target is not None and v <= self.target
                if rd % self.eval_every == 0 \
                        or (done_in_stage + j + 1) == n_rounds \
                        or (at_target and hit is None):
                    self.history.append(
                        Record(rd, self.iters_done + (j + 1) * k, v))
                if at_target and hit is None:
                    hit = rd
            self.rounds_done += n
            self.iters_done += n * k
            done_in_stage += n
            status.rounds += n
            status.iters += n * k
            if hit is not None:
                status.stop = True
                break
            if self.max_rounds is not None \
                    and self.rounds_done >= self.max_rounds:
                status.stop = True
                break
        self.params, self.mom, tg, self.comm_state = carry
        self.t_global = float(tg)
        return status

    def finish(self, engine: Engine) -> List[Record]:
        return self.history


def run(loss_fn: Callable, init_params, client_data, cfg: TrainConfig,
        eval_fn: Callable, *, eval_every: int = 1, max_rounds: Optional[int] = None,
        target: Optional[float] = None, lr_alpha: float = 0.0,
        chunk_rounds: int = 32, reducer=None, topology=None) -> List[Record]:
    """Run ``cfg.algo`` and return the (comm-round, objective) trace.

    loss_fn(params, batch) -> scalar (per-client minibatch loss).
    client_data: pytree with leading client axis N on every leaf.
    eval_fn(params) -> scalar on the *averaged* model.
    ``chunk_rounds`` communication rounds are scanned inside one jit call
    (with per-round eval), so the Python loop runs ~chunk_rounds× less often.
    ``reducer`` — a comm.Reducer or spec string for the communication round;
    defaults to ``cfg.reducer`` (DenseMean unless configured otherwise),
    which is bit-exact with the historical dense path.
    ``topology`` — an engine.Topology or spec string ("star" | "hier");
    defaults to ``cfg.topology`` with ``reducer`` on the first hop.
    """
    engine = Engine(cfg.algo, cfg, topology=topology, reducer=reducer)
    backend = VmapSimulatorBackend(
        loss_fn, init_params, client_data, eval_fn, eval_every=eval_every,
        max_rounds=max_rounds, target=target, lr_alpha=lr_alpha,
        chunk_rounds=chunk_rounds)
    return engine.run(backend)


def rounds_to_target(history: List[Record], target: float) -> Optional[int]:
    for rec in history:
        if rec.value <= target:
            return rec.round
    return None
