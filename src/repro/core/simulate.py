"""N-client Local-SGD simulator (single host, vmapped clients).

This is the engine behind the paper-fidelity convergence experiments
(Figures 1–4, Tables 1–2): N client replicas live on a stacked leading axis,
local steps are vmapped (no communication), and a communication round is a
``repro.comm`` reducer over the leading axis — DenseMean by default, which
is bit-exact Algorithm 1 semantics; compressed reducers (QuantizedMean,
TopKMean) trade per-round bytes for quantization noise with error feedback.

The same `Stage` objects drive this simulator and the distributed trainer
(core/local_sgd.py), so the convergence experiments validate exactly the
schedule code the production launcher runs.

Supported algorithms
  sync    — SyncSGD: k=1
  lb      — Large-batch SyncSGD: k=1, batch ×= lb_factor
  crpsgd  — CR-PSGD [38]: k=1, batch grows geometrically (masked fixed buffer)
  local   — Local SGD (Alg. 1), fixed k, optional η_t = η₁/(1+αt) decay
  stl_sc  — STL-SGD^sc (Alg. 2)
  stl_nc1 — STL-SGD^nc Option 1 (Alg. 3, geometric, prox surrogate)
  stl_nc2 — STL-SGD^nc Option 2 (Alg. 3, linear, prox surrogate)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import get_reducer
from repro.comm.reducer import Reducer
from repro.configs.base import TrainConfig
from repro.core import schedules as sched
from repro.core.prox import prox_loss
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading, tree_zeros_like

# fold_in salt deriving the reducer's rng from the round rng without
# consuming it — keeps the local-step rng stream (and thus the DenseMean
# trajectory) bit-identical to the pre-comm-subsystem dense path.
_COMM_SALT = 0x5EED


@dataclass
class Record:
    round: int      # communication rounds so far
    iteration: int  # total iterations so far
    value: float    # eval_fn(averaged params)


def _sample_batch(data, rng, batch: int):
    """data: client-local dict of arrays with leading dim n. Uniform minibatch."""
    n = jax.tree.leaves(data)[0].shape[0]
    idx = jax.random.randint(rng, (batch,), 0, n)
    return jax.tree.map(lambda a: a[idx], data)


def make_round_fn(loss_fn, *, k: int, batch: int, momentum: float,
                  lr_alpha: float, grow: float, b0: int, max_batch: int,
                  reducer: Optional[Reducer] = None):
    """One communication round = k vmapped local steps + 1 reduced average.

    Returned fn: (carry, rng, data, center, eta) -> carry where
    carry = (params_stacked, momentum_stacked, t_global_f32, comm_state).
    loss_fn(params, batch, center, weights) -> scalar.

    ``reducer`` (default DenseMean) owns the parameter average; its
    residual/error-feedback state rides in the carry. Momentum is always
    dense-averaged: it never leaves the client in a real deployment, the
    average only mirrors Alg. 1's replica-consensus bookkeeping.
    """
    reducer = reducer if reducer is not None else get_reducer(None)

    def batch_weights(t):
        if grow <= 1.0:
            return jnp.ones((batch,), jnp.float32) / batch
        bt = jnp.minimum(float(max_batch), float(b0) * grow ** t)
        bt = jnp.clip(jnp.round(bt), 1, batch)
        mask = (jnp.arange(batch) < bt).astype(jnp.float32)
        return mask / bt

    def round_fn(carry, rng_r, data, center, eta):
        N = jax.tree.leaves(carry[0])[0].shape[0]

        def local_step(c, rng_t):
            params, mom, t = c
            eta_t = eta / (1.0 + lr_alpha * t)
            w = batch_weights(t)

            def client(p, m, d, rng):
                b = _sample_batch(d, rng, batch)
                g = jax.grad(lambda q: loss_fn(q, b, center, w))(p)
                m2 = jax.tree.map(lambda mm, gg: momentum * mm + gg, m, g)
                p2 = jax.tree.map(lambda pp, mm: pp - eta_t * mm, p, m2)
                return p2, m2

            rngs = jax.random.split(rng_t, N)
            params, mom = jax.vmap(client)(params, mom, data, rngs)
            return (params, mom, t + 1.0), None

        params, mom, t, comm = carry
        (params, mom, t), _ = jax.lax.scan(
            local_step, (params, mom, t), jax.random.split(rng_r, k))
        consensus, comm = reducer.reduce(
            params, comm, jax.random.fold_in(rng_r, _COMM_SALT))
        params = tree_broadcast_leading(consensus, N)
        mom = tree_broadcast_leading(tree_mean_leading(mom), N)
        return (params, mom, t, comm)

    return round_fn


def run(loss_fn: Callable, init_params, client_data, cfg: TrainConfig,
        eval_fn: Callable, *, eval_every: int = 1, max_rounds: Optional[int] = None,
        target: Optional[float] = None, lr_alpha: float = 0.0,
        chunk_rounds: int = 32, reducer=None) -> List[Record]:
    """Run ``cfg.algo`` and return the (comm-round, objective) trace.

    loss_fn(params, batch) -> scalar (per-client minibatch loss).
    client_data: pytree with leading client axis N on every leaf.
    eval_fn(params) -> scalar on the *averaged* model.
    ``chunk_rounds`` communication rounds are scanned inside one jit call
    (with per-round eval), so the Python loop runs ~chunk_rounds× less often.
    ``reducer`` — a comm.Reducer or spec string for the communication round;
    defaults to ``cfg.reducer`` (DenseMean unless configured otherwise),
    which is bit-exact with the historical dense path.
    """
    N = jax.tree.leaves(client_data)[0].shape[0]
    algo = cfg.algo
    reducer = get_reducer(reducer if reducer is not None else cfg.reducer,
                          quant_bits=cfg.quant_bits, topk_frac=cfg.topk_frac)
    use_prox = algo in ("stl_nc1", "stl_nc2") and cfg.gamma_inv > 0.0
    ploss = prox_loss(loss_fn, cfg.gamma_inv if use_prox else 0.0)

    def wloss(params, batch, center, weights):
        if algo == "crpsgd":
            per = jax.vmap(
                lambda x: ploss(params, jax.tree.map(lambda a: a[None], x), center)
            )(batch)
            return jnp.sum(per * weights)
        return ploss(params, batch, center)

    grow = cfg.batch_growth if algo == "crpsgd" else 1.0
    stages = sched.make_stages(algo, cfg.eta1, cfg.T1, cfg.k1, cfg.n_stages, cfg.iid)

    params = tree_broadcast_leading(init_params, N)
    mom = tree_zeros_like(params)
    comm_state = reducer.init_state(params)  # residuals persist across stages
    rng = jax.random.key(cfg.seed)
    history: List[Record] = [Record(0, 0, float(eval_fn(init_params)))]
    rounds_done = 0
    iters_done = 0
    t_global = 0.0
    eval_jit = jax.jit(eval_fn)

    for stage in stages:
        if algo == "lb":
            k, b = 1, cfg.batch_per_client * 4
        elif algo == "crpsgd":
            k, b = 1, cfg.max_batch
        else:
            k, b = stage.k, cfg.batch_per_client
        round_fn = make_round_fn(
            wloss, k=k, batch=b, momentum=cfg.momentum, lr_alpha=lr_alpha,
            grow=grow, b0=cfg.batch_per_client, max_batch=cfg.max_batch,
            reducer=reducer)
        # Non-prox algorithms have no center: pass None (an empty pytree) so
        # nothing downstream can silently consume a stale parameter snapshot.
        center = tree_mean_leading(params) if use_prox else None

        @partial(jax.jit, static_argnames=("n",))
        def chunk_fn(carry, rng_c, data, ctr, eta, n):
            def body(c, rng_r):
                c = round_fn(c, rng_r, data, ctr, eta)
                return c, eval_fn(tree_mean_leading(c[0]))
            return jax.lax.scan(body, carry, jax.random.split(rng_c, n))

        n_rounds = -(-stage.T // k)  # ceil
        carry = (params, mom, jnp.asarray(t_global, jnp.float32), comm_state)
        done_in_stage = 0
        while done_in_stage < n_rounds:
            n = min(chunk_rounds, n_rounds - done_in_stage)
            rng, sub = jax.random.split(rng)
            carry, vals = chunk_fn(carry, sub, client_data, center, stage.eta, n)
            vals = list(map(float, vals))
            hit = None
            for j, v in enumerate(vals):
                rd = rounds_done + j + 1
                if rd % eval_every == 0 or (done_in_stage + j + 1) == n_rounds \
                        or (target is not None and v <= target and hit is None):
                    history.append(Record(rd, iters_done + (j + 1) * k, v))
                if target is not None and v <= target and hit is None:
                    hit = rd
            rounds_done += n
            iters_done += n * k
            done_in_stage += n
            if hit is not None:
                return history
            if max_rounds is not None and rounds_done >= max_rounds:
                return history
        params, mom, tg, comm_state = carry
        t_global = float(tg)

    return history


def rounds_to_target(history: List[Record], target: float) -> Optional[int]:
    for rec in history:
        if rec.value <= target:
            return rec.round
    return None
