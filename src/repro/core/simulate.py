"""N-client Local-SGD simulator — the vmapped execution backend.

This is the engine behind the paper-fidelity convergence experiments
(Figures 1–4, Tables 1–2): N client replicas live on a stacked leading axis,
local steps are vmapped (no communication), and a communication round is a
``repro.engine`` Topology reduction over the leading axis — a Star of the
configured ``repro.comm`` reducer by default (DenseMean is bit-exact
Algorithm 1 semantics), or a Hierarchical pod topology composing a dense
intra-pod average with a compressed inter-pod round.

Since the engine refactor this module is a *backend*: ``run()`` resolves
``cfg.algo`` through ``repro.engine.get_algorithm`` and hands a
``VmapSimulatorBackend`` to ``Engine.run`` — the SyncPolicy owns the stage
stream, the LocalUpdate owns the batch rule (large-batch / growing-batch
baselines included), and the same Engine drives the distributed
``StagewiseDriver``. The historical signature and the DenseMean trajectory
are preserved bit-for-bit (regression-pinned in tests/test_engine.py).

Algorithm names accepted by ``run`` are whatever the registry knows:
  sync, lb, crpsgd, local, stl_sc, stl_nc1, stl_nc2 (see repro.engine).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import get_reducer
from repro.configs.base import TrainConfig
from repro.core.prox import prox_loss
from repro.engine.engine import Engine, StageStatus
from repro.obs.trace import CAT_COMM, CAT_COMPUTE
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading, tree_zeros_like

# fold_in salt deriving the reducer's rng from the round rng without
# consuming it — keeps the local-step rng stream (and thus the DenseMean
# trajectory) bit-identical to the pre-comm-subsystem dense path.
_COMM_SALT = 0x5EED


@dataclass
class Record:
    round: int      # communication rounds so far
    iteration: int  # total iterations so far
    value: float    # eval_fn(averaged params)


def _sample_batch(data, rng, batch: int):
    """data: client-local dict of arrays with leading dim n. Uniform minibatch."""
    n = jax.tree.leaves(data)[0].shape[0]
    idx = jax.random.randint(rng, (batch,), 0, n)
    return jax.tree.map(lambda a: a[idx], data)


def make_batch_weights(batch: int, grow: float, b0: int, max_batch: int):
    """Per-example weight rule shared by every execution path.

    grow ≤ 1: uniform 1/batch. grow > 1 (CR-PSGD): bt = min(max, b0·grow^t)
    realised as a masked fixed-size buffer so compiled steps stay
    shape-stable.
    """

    def batch_weights(t):
        if grow <= 1.0:
            return jnp.ones((batch,), jnp.float32) / batch
        bt = jnp.minimum(float(max_batch), float(b0) * grow ** t)
        bt = jnp.clip(jnp.round(bt), 1, batch)
        mask = (jnp.arange(batch) < bt).astype(jnp.float32)
        return mask / bt

    return batch_weights


def client_sgd_step(loss_fn, batch: int, momentum: float,
                    p, m, d, rng, center, w, eta_t):
    """One client's minibatch SGD(+momentum) step.

    The single copy of the inner update math — the vmapped round, the
    masked-dropout round, the adaptive probe step and the async client job
    (repro.runtime) all call this, so the execution paths cannot drift.
    """
    b = _sample_batch(d, rng, batch)
    g = jax.grad(lambda q: loss_fn(q, b, center, w))(p)
    m2 = jax.tree.map(lambda mm, gg: momentum * mm + gg, m, g)
    p2 = jax.tree.map(lambda pp, mm: pp - eta_t * mm, p, m2)
    return p2, m2


def make_round_fn(loss_fn, *, k: int, batch: int, momentum: float,
                  lr_alpha: float, grow: float, b0: int, max_batch: int,
                  reducer=None, masked: bool = False):
    """One communication round = k vmapped local steps + 1 reduced average.

    Returned fn: (carry, rng, data, center, eta) -> carry where
    carry = (params_stacked, momentum_stacked, t_global_f32, comm_state).
    loss_fn(params, batch, center, weights) -> scalar.

    ``reducer`` (default DenseMean) owns the parameter average — any object
    with the reduce/init_state protocol works, i.e. a ``comm.Reducer`` or a
    ``engine.Topology``; its residual/error-feedback state rides in the
    carry. Momentum is always dense-averaged: it never leaves the client in
    a real deployment, the average only mirrors Alg. 1's replica-consensus
    bookkeeping.

    ``masked=True`` returns the dropout-aware variant (used by
    ``repro.runtime.EventBackend``) taking a trailing (N,) bool mask:
    inactive clients are frozen for the round's k local steps — they missed
    their compute window — but the reduce still spans all N replicas, so a
    dropped client contributes a zero delta (plus, under error-feedback
    reducers, whatever residual it already carried, which keeps the EF
    state convergent) and compressed/hierarchical topologies compose with
    partial participation unchanged. One round body serves both variants;
    the unmasked trace is bit-identical to the historical dense path.
    """
    reducer = reducer if reducer is not None else get_reducer(None)
    batch_weights = make_batch_weights(batch, grow, b0, max_batch)

    def round_body(carry, rng_r, data, center, eta, mask):
        N = jax.tree.leaves(carry[0])[0].shape[0]

        def local_step(c, rng_t):
            params, mom, t = c
            eta_t = eta / (1.0 + lr_alpha * t)
            w = batch_weights(t)

            def client(p, m, d, rng, active=None):
                p2, m2 = client_sgd_step(loss_fn, batch, momentum, p, m, d,
                                         rng, center, w, eta_t)
                if active is None:
                    return p2, m2
                freeze = lambda new, old: jax.tree.map(
                    lambda a, o: jnp.where(active, a, o), new, old)
                return freeze(p2, p), freeze(m2, m)

            rngs = jax.random.split(rng_t, N)
            if mask is None:
                params, mom = jax.vmap(
                    lambda p, m, d, rng: client(p, m, d, rng)
                )(params, mom, data, rngs)
            else:
                params, mom = jax.vmap(client)(params, mom, data, rngs, mask)
            return (params, mom, t + 1.0), None

        params, mom, t, comm = carry
        (params, mom, t), _ = jax.lax.scan(
            local_step, (params, mom, t), jax.random.split(rng_r, k))
        consensus, comm = reducer.reduce(
            params, comm, jax.random.fold_in(rng_r, _COMM_SALT))
        params = tree_broadcast_leading(consensus, N)
        mom = tree_broadcast_leading(tree_mean_leading(mom), N)
        return (params, mom, t, comm)

    if masked:
        return round_body
    return lambda carry, rng_r, data, center, eta: round_body(
        carry, rng_r, data, center, eta, None)


def make_local_step_fn(loss_fn, *, batch: int, momentum: float,
                       lr_alpha: float, grow: float, b0: int, max_batch: int):
    """One vmapped local step for all N clients, *no* communication.

    The probe-granularity sibling of ``make_round_fn`` (same client math,
    via ``client_sgd_step``), used by the divergence-triggered
    ``AdaptivePeriod`` policy where the backend decides after every step
    whether to run the round.
    """
    batch_weights = make_batch_weights(batch, grow, b0, max_batch)

    def step_fn(params, mom, t, rng_t, data, center, eta):
        N = jax.tree.leaves(params)[0].shape[0]
        eta_t = eta / (1.0 + lr_alpha * t)
        w = batch_weights(t)
        rngs = jax.random.split(rng_t, N)
        params, mom = jax.vmap(
            lambda p, m, d, rng: client_sgd_step(
                loss_fn, batch, momentum, p, m, d, rng, center, w, eta_t)
        )(params, mom, data, rngs)
        return params, mom, t + 1.0

    return step_fn


def replica_divergence(stacked):
    """Relative replica spread: Σ_leaves mean_i ‖x_i − x̄‖² / (‖x̄‖² + ε).

    The probe the AdaptivePeriod policy thresholds — zero right after a
    round (replicas identical), growing with local drift.
    """
    mean = tree_mean_leading(stacked)
    num = 0.0
    den = 0.0
    for x, m in zip(jax.tree.leaves(stacked), jax.tree.leaves(mean)):
        d = x.astype(jnp.float32) - m[None].astype(jnp.float32)
        num += jnp.mean(jnp.sum(d * d, axis=tuple(range(1, d.ndim))))
        den += jnp.sum(m.astype(jnp.float32) ** 2)
    return num / (den + 1e-12)


class VmapSimulatorBackend:
    """Engine backend: N vmapped client replicas on one host.

    Owns the chunked-scan execution of each stage (``chunk_rounds``
    communication rounds per jit call, per-round eval inside the scan), the
    (round, objective) history, and the target/max_rounds early exit.
    Compiled chunk functions are cached per (k, batch) — stages that only
    change η reuse the compilation (η is a traced operand).
    """

    def __init__(self, loss_fn: Callable, init_params, client_data,
                 eval_fn: Callable, *, eval_every: int = 1,
                 max_rounds: Optional[int] = None,
                 target: Optional[float] = None, lr_alpha: float = 0.0,
                 chunk_rounds: int = 32):
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.client_data = client_data
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self.max_rounds = max_rounds
        self.target = target
        self.lr_alpha = lr_alpha
        self.chunk_rounds = chunk_rounds

    def setup(self, engine: Engine):
        cfg = engine.cfg
        algo = engine.algorithm
        N = jax.tree.leaves(self.client_data)[0].shape[0]
        self.use_prox = algo.uses_center(cfg)
        ploss = prox_loss(self.loss_fn, algo.gamma_inv(cfg))
        self.wloss = algo.local_update.make_loss(ploss)
        self.batch = algo.local_update.round_batch(cfg)
        self.grow = algo.local_update.growth(cfg)

        self.params = tree_broadcast_leading(self.init_params, N)
        self.mom = tree_zeros_like(self.params)
        self.comm_state = engine.topology.init_state(self.params)
        self.rng = jax.random.key(cfg.seed)
        self.history: List[Record] = [
            Record(0, 0, float(self.eval_fn(self.init_params)))]
        self.rounds_done = 0
        self.iters_done = 0
        self.t_global = 0.0
        self._chunk_cache = {}
        engine.set_cost_basis(self.init_params, N)

    def _chunk_fn(self, engine: Engine, k: int, b: int):
        key = (k, b)
        if key not in self._chunk_cache:
            cfg = engine.cfg
            round_fn = make_round_fn(
                self.wloss, k=k, batch=b, momentum=cfg.momentum,
                lr_alpha=self.lr_alpha, grow=self.grow,
                b0=cfg.batch_per_client, max_batch=cfg.max_batch,
                reducer=engine.topology)
            eval_fn = self.eval_fn

            @partial(jax.jit, static_argnames=("n",))
            def chunk_fn(carry, rng_c, data, ctr, eta, n):
                def body(c, rng_r):
                    c = round_fn(c, rng_r, data, ctr, eta)
                    return c, eval_fn(tree_mean_leading(c[0]))
                return jax.lax.scan(body, carry, jax.random.split(rng_c, n))

            self._chunk_cache[key] = chunk_fn
        return self._chunk_cache[key]

    def _sample_round_masks(self, n: int):
        """Per-(round, client) participation masks for the next n rounds.

        None (the default) means full participation and the unmasked chunk
        function; ``repro.runtime.EventBackend`` overrides this (and
        ``_chunk_fn``) to thread dropout masks through the rounds.
        """
        return None

    def run_stage(self, stage, engine: Engine) -> StageStatus:
        policy = engine.algorithm.sync_policy
        if getattr(policy, "asynchronous", False):
            raise ValueError(
                "asynchronous policies (barrier-free rounds) need the "
                "event-driven backend: use repro.runtime.EventBackend / "
                "runtime.run instead of the vmapped simulator")
        if getattr(policy, "adaptive", False):
            return self._run_stage_adaptive(stage, engine)
        k = stage.k
        chunk_fn = self._chunk_fn(engine, k, self.batch)
        # Non-prox algorithms have no center: pass None (an empty pytree) so
        # nothing downstream can silently consume a stale parameter snapshot.
        center = tree_mean_leading(self.params) if self.use_prox else None

        status = StageStatus()
        n_rounds = -(-stage.T // k)  # ceil
        carry = (self.params, self.mom,
                 jnp.asarray(self.t_global, jnp.float32), self.comm_state)
        done_in_stage = 0
        while done_in_stage < n_rounds:
            n = min(self.chunk_rounds, n_rounds - done_in_stage)
            self.rng, sub = jax.random.split(self.rng)
            masks = self._sample_round_masks(n)
            # one wall span per jit chunk — the host-visible unit of work
            # (n fused rounds of k local steps + reduce each)
            with engine.tracer.span("local_steps", cat=CAT_COMPUTE,
                                    track="simulator",
                                    attrs={"s": stage.s, "rounds": n,
                                           "k": k, "eta": stage.eta}):
                if masks is None:
                    carry, vals = chunk_fn(carry, sub, self.client_data,
                                           center, stage.eta, n)
                else:
                    carry, vals = chunk_fn(carry, sub, self.client_data,
                                           center, stage.eta,
                                           jnp.asarray(masks), n)
                vals = list(map(float, vals))
            hit = None
            for j, v in enumerate(vals):
                rd = self.rounds_done + j + 1
                at_target = self.target is not None and v <= self.target
                if rd % self.eval_every == 0 \
                        or (done_in_stage + j + 1) == n_rounds \
                        or (at_target and hit is None):
                    self.history.append(
                        Record(rd, self.iters_done + (j + 1) * k, v))
                if at_target and hit is None:
                    hit = rd
            self.rounds_done += n
            self.iters_done += n * k
            done_in_stage += n
            status.rounds += n
            status.iters += n * k
            if hit is not None:
                status.stop = True
                break
            if self.max_rounds is not None \
                    and self.rounds_done >= self.max_rounds:
                status.stop = True
                break
        self.params, self.mom, tg, self.comm_state = carry
        self.t_global = float(tg)
        # steps-per-round breakdown for event-clock overlays (EventBackend)
        self._last_round_steps = [k] * status.rounds
        engine.metrics.gauge(
            "train.stage_objective", unit="objective",
            help="eval_fn(averaged params) at stage end").set(
                self.history[-1].value, stage=stage.s)
        return status

    # -- divergence-triggered periods (AdaptivePeriod) ----------------------

    def _adaptive_fns(self, engine: Engine, b: int):
        key = ("adaptive", b)
        if key not in self._chunk_cache:
            cfg = engine.cfg
            step = make_local_step_fn(
                self.wloss, batch=b, momentum=cfg.momentum,
                lr_alpha=self.lr_alpha, grow=self.grow,
                b0=cfg.batch_per_client, max_batch=cfg.max_batch)
            topo = engine.topology

            @jax.jit
            def step_fn(params, mom, t, rng, data, center, eta):
                params, mom, t = step(params, mom, t, rng, data, center, eta)
                return params, mom, t, replica_divergence(params)

            @jax.jit
            def sync_fn(params, mom, comm, rng):
                N = jax.tree.leaves(params)[0].shape[0]
                consensus, comm = topo.reduce(params, comm, rng)
                return (tree_broadcast_leading(consensus, N),
                        tree_broadcast_leading(tree_mean_leading(mom), N),
                        comm, consensus)

            self._chunk_cache[key] = (step_fn, sync_fn)
        return self._chunk_cache[key]

    def _run_stage_adaptive(self, stage, engine: Engine) -> StageStatus:
        """Probe-and-trigger loop: one vmapped local step at a time; the
        round runs when replica divergence crosses the policy threshold, the
        stage's k-cap is hit, or the stage ends."""
        policy = engine.algorithm.sync_policy
        step_fn, sync_fn = self._adaptive_fns(engine, self.batch)
        center = tree_mean_leading(self.params) if self.use_prox else None

        status = StageStatus()
        self._last_round_steps = []
        params, mom = self.params, self.mom
        t = jnp.asarray(self.t_global, jnp.float32)
        since_sync = 0
        for it in range(stage.T):
            self.rng, sub = jax.random.split(self.rng)
            params, mom, t, div = step_fn(params, mom, t, sub,
                                          self.client_data, center, stage.eta)
            since_sync += 1
            self.iters_done += 1
            status.iters += 1
            last = it == stage.T - 1
            if not (last or since_sync >= stage.k
                    or float(div) >= policy.threshold):
                continue
            with engine.tracer.span("reduce", cat=CAT_COMM,
                                    track="simulator",
                                    attrs={"s": stage.s,
                                           "steps": since_sync}):
                params, mom, self.comm_state, consensus = sync_fn(
                    params, mom, self.comm_state,
                    jax.random.fold_in(sub, _COMM_SALT))
            status.rounds += 1
            self.rounds_done += 1
            self._last_round_steps.append(since_sync)
            since_sync = 0
            v = float(self.eval_fn(consensus))
            at_target = self.target is not None and v <= self.target
            if self.rounds_done % self.eval_every == 0 or last or at_target:
                self.history.append(Record(self.rounds_done, self.iters_done,
                                           v))
            if at_target or (self.max_rounds is not None
                             and self.rounds_done >= self.max_rounds):
                status.stop = True
                break
        self.params, self.mom = params, mom
        self.t_global = float(t)
        engine.metrics.gauge(
            "train.stage_objective", unit="objective",
            help="eval_fn(averaged params) at stage end").set(
                self.history[-1].value, stage=stage.s)
        return status

    def finish(self, engine: Engine) -> List[Record]:
        return self.history


def run(loss_fn: Callable, init_params, client_data, cfg: TrainConfig,
        eval_fn: Callable, *, eval_every: int = 1, max_rounds: Optional[int] = None,
        target: Optional[float] = None, lr_alpha: float = 0.0,
        chunk_rounds: int = 32, reducer=None, topology=None,
        tracer=None) -> List[Record]:
    """Run ``cfg.algo`` and return the (comm-round, objective) trace.

    loss_fn(params, batch) -> scalar (per-client minibatch loss).
    client_data: pytree with leading client axis N on every leaf.
    eval_fn(params) -> scalar on the *averaged* model.
    ``chunk_rounds`` communication rounds are scanned inside one jit call
    (with per-round eval), so the Python loop runs ~chunk_rounds× less often.
    ``reducer`` — a comm.Reducer or spec string for the communication round;
    defaults to ``cfg.reducer`` (DenseMean unless configured otherwise),
    which is bit-exact with the historical dense path.
    ``topology`` — an engine.Topology or spec string ("star" | "hier");
    defaults to ``cfg.topology`` with ``reducer`` on the first hop.
    ``tracer`` — an ``obs.Tracer`` to record wall/modeled span timelines
    into (None = disabled, zero overhead).
    """
    engine = Engine(cfg.algo, cfg, topology=topology, reducer=reducer,
                    tracer=tracer)
    backend = VmapSimulatorBackend(
        loss_fn, init_params, client_data, eval_fn, eval_every=eval_every,
        max_rounds=max_rounds, target=target, lr_alpha=lr_alpha,
        chunk_rounds=chunk_rounds)
    return engine.run(backend)


def rounds_to_target(history: List[Record], target: float) -> Optional[int]:
    for rec in history:
        if rec.value <= target:
            return rec.round
    return None
