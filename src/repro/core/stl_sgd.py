"""STL-SGD stagewise driver — the pjit execution backend.

Orchestrates any registered algorithm over (train_step_local, sync_step)
pairs built by ``core.local_sgd``: per stage s the SyncPolicy fixes η_s,
the driver runs T_s local iterations and triggers the parameter-averaging
round every ⌊k_s⌋ steps; for the ^nc variants the loss is the prox
surrogate f^γ centered at the stage-start average.

Since the engine refactor, ``StagewiseDriver.run`` is a thin wrapper: it
hands a ``DriverBackend`` to the same ``repro.engine.Engine`` that drives
the vmapped simulator, so both front-ends consume one stage stream and one
topology-priced comm ledger. The driver is step-function-agnostic — the
tests drive it with tiny CPU models, the launcher with pjit'd multi-pod
steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import NetworkModel, get_reducer, link_model
from repro.configs.base import TrainConfig
from repro.core.local_sgd import sync_step_tags
from repro.engine.algorithm import get_algorithm
from repro.engine.engine import Engine, StageStatus
from repro.engine.topology import Hierarchical, Star, StreamingStar
from repro.obs.trace import CAT_COMM, CAT_COMPUTE
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading
from repro.utils.logging import get_logger

log = get_logger("stl_sgd")


def driver_state(params, n_clients: int) -> dict:
    """Stacked {"params", "opt", "step"} driver state from one replica.

    The state layout ``StagewiseDriver`` and ``local_sgd.build_sync_step``
    expect: every client starts from the same ``params``, momentum buffers
    zeroed, step counter 0.
    """
    stacked = tree_broadcast_leading(params, n_clients)
    return {"params": stacked,
            "opt": {"mu": jax.tree.map(jnp.zeros_like, stacked)},
            "step": jnp.zeros((), jnp.int32)}


def make_client_sgd_step(loss_fn, client_data, batch: int, seed: int = 1):
    """Ready-made ``train_step`` over stacked client data shards.

    One vmapped minibatch SGD step per client on its own shard of
    ``client_data`` (a pytree with leading client axis); the minibatch rng
    derives from ``state["step"]`` so the step is pure and the batch
    stream needs no real payload (drive the driver with
    ``itertools.repeat(None)``). The harness behind the hierarchical
    driver demos (``examples/hierarchical_pods.py``,
    ``benchmarks/table4_comm_cost.py``).
    """
    n_clients = jax.tree.leaves(client_data)[0].shape[0]

    def train_step(state, _, eta):
        def client(p, d, r):
            n = jax.tree.leaves(d)[0].shape[0]
            idx = jax.random.randint(r, (batch,), 0, n)
            b = jax.tree.map(lambda a: a[idx], d)
            loss, g = jax.value_and_grad(lambda q: loss_fn(q, b))(p)
            return jax.tree.map(lambda a, gg: a - eta * gg, p, g), loss

        rngs = jax.random.split(
            jax.random.fold_in(jax.random.key(seed), state["step"]),
            n_clients)
        params, losses = jax.vmap(client)(state["params"], client_data, rngs)
        return dict(state, params=params, step=state["step"] + 1), {
            "loss": jnp.mean(losses)}

    return train_step


@dataclass
class StageResult:
    stage: int
    eta: float
    k: int
    iters: int
    rounds: int
    mean_loss: float


@dataclass
class DriverState:
    state: dict                 # {"params","opt","step"} with client axis
    center: Optional[dict] = None  # prox center (^nc)
    results: List[StageResult] = field(default_factory=list)
    rounds_total: int = 0
    iters_total: int = 0
    comm_bytes_total: int = 0      # modeled bytes moved by sync rounds
    comm_time_s: float = 0.0       # α–β modeled wall-clock of those rounds
    # per-(leaf, hop) totals ({"leaf","path","hop","bytes","time_s"}); the
    # streaming round's ledger — sums reconcile with the tree-level totals
    # above (bytes bit-exactly, seconds to float-sum precision)
    leaf_ledger: List[dict] = field(default_factory=list)


class DriverBackend:
    """Engine backend: a stream of pjit step calls on real batches."""

    def __init__(self, driver: "StagewiseDriver", ds: DriverState, batches,
                 max_iters: Optional[int]):
        self.driver = driver
        self.ds = ds
        self.it = iter(batches)
        self.max_iters = max_iters

    def setup(self, engine: Engine):
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            self.ds.state["params"])
        n_clients = jax.tree.leaves(self.ds.state["params"])[0].shape[0]
        engine.set_cost_basis(template, n_clients)

    def run_stage(self, stage, engine: Engine) -> StageStatus:
        drv, ds = self.driver, self.ds
        if drv.uses_center:
            ds.center = tree_mean_leading(ds.state["params"])
        losses = []
        status = StageStatus()
        done = 0
        tracer = engine.tracer
        while done < stage.T:
            burst = min(stage.k, stage.T - done)
            with tracer.span("local_steps", cat=CAT_COMPUTE, track="driver",
                             attrs={"s": stage.s, "steps": burst,
                                    "eta": stage.eta}):
                for _ in range(burst):
                    batch = next(self.it)
                    if drv.uses_center:
                        ds.state, m = drv.train_step(ds.state, batch,
                                                     stage.eta, ds.center)
                    else:
                        ds.state, m = drv.train_step(ds.state, batch,
                                                     stage.eta)
                    losses.append(float(m["loss"]))
                    done += 1
                    ds.iters_total += 1
                    if self.max_iters and ds.iters_total >= self.max_iters:
                        break
            with tracer.span("reduce", cat=CAT_COMM, track="driver",
                             attrs=dict(drv.span_attrs, s=stage.s)):
                ds.state = drv.sync_step(ds.state)
            status.rounds += 1
            ds.rounds_total += 1
            if self.max_iters and ds.iters_total >= self.max_iters:
                status.stop = True
                break
        status.iters = done
        res = StageResult(stage.s, stage.eta, stage.k, done, status.rounds,
                          float(jnp.mean(jnp.asarray(losses))) if losses
                          else float("nan"))
        ds.results.append(res)
        engine.metrics.gauge(
            "train.stage_objective", unit="loss",
            help="mean training loss per stage").set(res.mean_loss,
                                                     stage=res.stage)
        log.info("stage_done", stage=res.stage, eta=res.eta, k=res.k,
                 iters=res.iters, rounds=res.rounds, loss=res.mean_loss)
        return status

    def finish(self, engine: Engine) -> DriverState:
        self.ds.comm_bytes_total = engine.report.comm_bytes_total
        self.ds.comm_time_s = engine.report.comm_time_s
        self.ds.leaf_ledger = engine.leaf_ledger()
        return self.ds


class StagewiseDriver:
    """Runs cfg.algo over a stream of batches.

    train_step(state, batch, eta[, center]) -> (state, metrics)
    sync_step(state) -> state

    The sync round's *shape* follows the sync_step's tags (set by
    ``local_sgd.build_sync_step``; explicit args and ``tcfg.topology``
    must agree with them): flat star (default), per-leaf streaming star
    (``streaming=True``), or the two-level hierarchical round
    (``hierarchical=True`` — dense intra-pod over ``data``, compressed
    inter-pod over ``pod``; ``tcfg.n_pods`` / ``tcfg.inter_reducer``).
    The engine then prices exactly that topology, so
    ``DriverState.comm_bytes_total`` and the per-(leaf, hop)
    ``leaf_ledger`` always describe the collectives the run emitted.
    """

    def __init__(self, tcfg: TrainConfig, train_step: Callable,
                 sync_step: Callable, uses_center: bool = False,
                 reducer=None):
        self.tcfg = tcfg
        self.train_step = train_step
        self.sync_step = sync_step
        self.uses_center = uses_center
        # Comm accounting reducer, in priority order: explicit arg > the
        # reducer the sync_step itself was built with (local_sgd.
        # build_sync_step tags it, surviving jax.jit via __wrapped__) >
        # tcfg.reducer. The tag keeps accounting from silently diverging
        # from what the round actually transmits — the driver prices
        # exactly the topology the sync_step executes (flat star,
        # per-leaf streaming star, or the two-level hierarchical round).
        tags = sync_step_tags(sync_step)

        def tag(name, default=None):
            v = tags.get(name)
            return default if v is None else v

        if reducer is None:
            reducer = tag("reducer")
        self.reducer = get_reducer(
            reducer if reducer is not None else tcfg.reducer,
            quant_bits=tcfg.quant_bits, topk_frac=tcfg.topk_frac)
        topo_spec = getattr(tcfg, "topology", "star")
        stream_hier_specs = ("streaming-hier", "hier-streaming",
                             "streaming-hierarchical")
        hier_spec = (topo_spec in ("hier", "hierarchical", "pods")
                     or topo_spec in stream_hier_specs)
        # a sync_step built with build_sync_step(streaming=True) implies the
        # per-leaf round even when the config says plain "star"
        self.streaming = (topo_spec in ("streaming", "streaming-star",
                                        "stream")
                          or topo_spec in stream_hier_specs
                          or bool(tag("streaming", False)))
        # ... and a hierarchical-tagged sync_step implies the two-level
        # round the same way. cfg n_pods=1 is the flat degenerate case
        # (no inter-pod link exists; build_sync_step emits the flat round).
        # streaming and hierarchical compose: the per-leaf two-level round
        # (Hierarchical(streaming=True)) prices like the blocking one.
        self.hierarchical = bool(tag("hierarchical", False)) or (
            hier_spec and getattr(tcfg, "n_pods", 2) > 1)
        if self.hierarchical:
            if not tag("hierarchical", False):
                # cfg promises a two-level round but the step transmits a
                # flat average: pricing Hierarchical would ledger bytes
                # the collectives never move.
                raise ValueError(
                    f"topology={tcfg.topology!r} needs a two-level sync "
                    f"step: build it with local_sgd.build_sync_step("
                    f"reducer, hierarchical=True, n_pods={tcfg.n_pods}, "
                    f"inter_reducer={tcfg.inter_reducer!r})")
            n_pods = tag("n_pods")
            if hier_spec and n_pods != tcfg.n_pods:
                raise ValueError(
                    f"sync_step reduces over {n_pods} pods but the config "
                    f"says n_pods={tcfg.n_pods}; the ledger would price a "
                    f"different topology than the round executes")
            self.n_pods = n_pods
            self.inter_reducer = get_reducer(
                tag("inter_reducer", getattr(tcfg, "inter_reducer", "int8")),
                quant_bits=tcfg.quant_bits, topk_frac=tcfg.topk_frac)
            cfg_inter = get_reducer(getattr(tcfg, "inter_reducer", "int8"),
                                    quant_bits=tcfg.quant_bits,
                                    topk_frac=tcfg.topk_frac)
            if hier_spec and tag("inter_reducer") is not None \
                    and self.inter_reducer.name != cfg_inter.name:
                # same contract as the n_pods check: cfg-derived reports
                # (comm_summary_for) and the executed ledger must price
                # the same WAN hop
                raise ValueError(
                    f"sync_step compresses the inter-pod hop with "
                    f"{self.inter_reducer.name!r} but the config says "
                    f"inter_reducer={tcfg.inter_reducer!r}; the ledger "
                    f"would price a different round than the one executed")
        elif topo_spec not in (None, "star", "flat", "streaming",
                               "streaming-star", "stream") and not hier_spec:
            raise ValueError(
                f"unknown topology spec for StagewiseDriver: "
                f"{tcfg.topology!r} (expected star/streaming/hierarchical/"
                f"streaming-hier)")
        self.net = NetworkModel(
            latency_s=tcfg.comm_latency_s,
            bandwidth_gbps=tcfg.comm_bandwidth_gbps,
            count_downlink=getattr(tcfg, "count_downlink", False))
        self.algorithm = get_algorithm(tcfg.algo)
        policy = self.algorithm.sync_policy
        if getattr(policy, "asynchronous", False):
            # the driver's (train_step, sync_step) contract is a barriered
            # fixed-schedule round; running these policies here would
            # silently execute the wrong semantics under the right name
            raise ValueError(
                f"StagewiseDriver runs barriered fixed-schedule rounds, but "
                f"algorithm {self.algorithm.name!r} carries the asynchronous "
                f"{type(policy).__name__} policy (merge-on-arrival, no "
                f"barrier). Run it on the event runtime instead: "
                f"repro.runtime.run / repro.runtime.EventBackend")
        if getattr(policy, "adaptive", False):
            raise ValueError(
                f"StagewiseDriver runs barriered fixed-schedule rounds, but "
                f"algorithm {self.algorithm.name!r} carries the "
                f"{type(policy).__name__} policy, whose divergence probe "
                f"decides each round at runtime. Run it on the vmapped "
                f"simulator (core.simulate.run) or the event runtime "
                f"(repro.runtime.EventBackend)")
        self.stages = self.algorithm.stages(tcfg)
        # trace-span attributes of one sync round — derived from the same
        # tags the ledger prices, so trace and ledger agree by construction
        self.span_attrs = {"reducer": self.reducer.name,
                           "streaming": self.streaming,
                           "hierarchical": self.hierarchical}
        if self.hierarchical:
            self.span_attrs.update(n_pods=self.n_pods,
                                   inter_reducer=self.inter_reducer.name)

    def build_topology(self):
        """The priced Topology of one sync round — exactly the round the
        tagged sync_step executes. Streaming rounds price identically to
        Star (same bytes, same serial α–β time) but additionally carry
        the per-leaf ledger; hierarchical rounds price per hop
        (calibrated ICI intra-pod, the config's α–β link inter-pod).
        Also what ``--profile`` uses to price one sync step."""
        if self.hierarchical:
            return Hierarchical(n_pods=self.n_pods, intra=self.reducer,
                                inter=self.inter_reducer,
                                intra_net=link_model("ici"),
                                inter_net=self.net,
                                streaming=self.streaming)
        topo_cls = StreamingStar if self.streaming else Star
        return topo_cls(reducer=self.reducer, network=self.net)

    def run(self, state: dict, batches, max_iters: Optional[int] = None,
            tracer=None, series=None) -> DriverState:
        ds = DriverState(state=state)
        # a fresh Engine per run: its report is the run's comm ledger,
        # priced on exactly the topology the sync_step executes —
        # modeled and executed bytes cannot diverge.
        engine = Engine(self.algorithm, self.tcfg,
                        topology=self.build_topology(),
                        tracer=tracer, series=series)
        ds = engine.run(DriverBackend(self, ds, batches, max_iters))
        log.info("comm_summary", reducer=self.reducer.name,
                 rounds=ds.rounds_total, comm_bytes=ds.comm_bytes_total,
                 comm_time_s=ds.comm_time_s)
        return ds
