"""STL-SGD stagewise driver — the pjit execution backend.

Orchestrates any registered algorithm over (train_step_local, sync_step)
pairs built by ``core.local_sgd``: per stage s the SyncPolicy fixes η_s,
the driver runs T_s local iterations and triggers the parameter-averaging
round every ⌊k_s⌋ steps; for the ^nc variants the loss is the prox
surrogate f^γ centered at the stage-start average.

Since the engine refactor, ``StagewiseDriver.run`` is a thin wrapper: it
hands a ``DriverBackend`` to the same ``repro.engine.Engine`` that drives
the vmapped simulator, so both front-ends consume one stage stream and one
topology-priced comm ledger. The driver is step-function-agnostic — the
tests drive it with tiny CPU models, the launcher with pjit'd multi-pod
steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import NetworkModel, get_reducer
from repro.configs.base import TrainConfig
from repro.engine.algorithm import get_algorithm
from repro.engine.engine import Engine, StageStatus
from repro.engine.topology import Star, StreamingStar
from repro.utils.tree import tree_mean_leading
from repro.utils.logging import get_logger

log = get_logger("stl_sgd")


@dataclass
class StageResult:
    stage: int
    eta: float
    k: int
    iters: int
    rounds: int
    mean_loss: float


@dataclass
class DriverState:
    state: dict                 # {"params","opt","step"} with client axis
    center: Optional[dict] = None  # prox center (^nc)
    results: List[StageResult] = field(default_factory=list)
    rounds_total: int = 0
    iters_total: int = 0
    comm_bytes_total: int = 0      # modeled bytes moved by sync rounds
    comm_time_s: float = 0.0       # α–β modeled wall-clock of those rounds
    # per-(leaf, hop) totals ({"leaf","path","hop","bytes","time_s"}); the
    # streaming round's ledger — sums reconcile with the tree-level totals
    # above (bytes bit-exactly, seconds to float-sum precision)
    leaf_ledger: List[dict] = field(default_factory=list)


class DriverBackend:
    """Engine backend: a stream of pjit step calls on real batches."""

    def __init__(self, driver: "StagewiseDriver", ds: DriverState, batches,
                 max_iters: Optional[int]):
        self.driver = driver
        self.ds = ds
        self.it = iter(batches)
        self.max_iters = max_iters

    def setup(self, engine: Engine):
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            self.ds.state["params"])
        n_clients = jax.tree.leaves(self.ds.state["params"])[0].shape[0]
        engine.set_cost_basis(template, n_clients)

    def run_stage(self, stage, engine: Engine) -> StageStatus:
        drv, ds = self.driver, self.ds
        if drv.uses_center:
            ds.center = tree_mean_leading(ds.state["params"])
        losses = []
        status = StageStatus()
        done = 0
        while done < stage.T:
            burst = min(stage.k, stage.T - done)
            for _ in range(burst):
                batch = next(self.it)
                if drv.uses_center:
                    ds.state, m = drv.train_step(ds.state, batch, stage.eta,
                                                 ds.center)
                else:
                    ds.state, m = drv.train_step(ds.state, batch, stage.eta)
                losses.append(float(m["loss"]))
                done += 1
                ds.iters_total += 1
                if self.max_iters and ds.iters_total >= self.max_iters:
                    break
            ds.state = drv.sync_step(ds.state)
            status.rounds += 1
            ds.rounds_total += 1
            if self.max_iters and ds.iters_total >= self.max_iters:
                status.stop = True
                break
        status.iters = done
        res = StageResult(stage.s, stage.eta, stage.k, done, status.rounds,
                          float(jnp.mean(jnp.asarray(losses))) if losses
                          else float("nan"))
        ds.results.append(res)
        log.info("stage %d: eta=%.3g k=%d iters=%d rounds=%d loss=%.4f",
                 res.stage, res.eta, res.k, res.iters, res.rounds,
                 res.mean_loss)
        return status

    def finish(self, engine: Engine) -> DriverState:
        self.ds.comm_bytes_total = engine.report.comm_bytes_total
        self.ds.comm_time_s = engine.report.comm_time_s
        self.ds.leaf_ledger = engine.leaf_ledger()
        return self.ds


class StagewiseDriver:
    """Runs cfg.algo over a stream of batches.

    train_step(state, batch, eta[, center]) -> (state, metrics)
    sync_step(state) -> state
    """

    def __init__(self, tcfg: TrainConfig, train_step: Callable,
                 sync_step: Callable, uses_center: bool = False,
                 reducer=None):
        self.tcfg = tcfg
        self.train_step = train_step
        self.sync_step = sync_step
        self.uses_center = uses_center
        # Comm accounting reducer, in priority order: explicit arg > the
        # reducer the sync_step itself was built with (local_sgd.
        # build_sync_step tags it, surviving jax.jit via __wrapped__) >
        # tcfg.reducer. The tag keeps accounting from silently diverging
        # from what the round actually transmits — which is also why the
        # driver always prices a Star topology: sync_step transmits flat.
        if reducer is None:
            reducer = getattr(sync_step, "reducer", None) or getattr(
                getattr(sync_step, "__wrapped__", None), "reducer", None)
        self.reducer = get_reducer(
            reducer if reducer is not None else tcfg.reducer,
            quant_bits=tcfg.quant_bits, topk_frac=tcfg.topk_frac)
        topo_spec = getattr(tcfg, "topology", "star")
        # a sync_step built with build_sync_step(streaming=True) implies the
        # per-leaf round even when the config says plain "star"
        self.streaming = (topo_spec in ("streaming", "streaming-star",
                                        "stream")
                          or bool(getattr(sync_step, "streaming", False)
                                  or getattr(getattr(sync_step, "__wrapped__",
                                                     None), "streaming",
                                             False)))
        if topo_spec not in (None, "star", "flat", "streaming",
                             "streaming-star", "stream"):
            # sync_step transmits a flat client-axis average; accepting a
            # hierarchical config here would make the driver's ledger and
            # comm_summary_for price different topologies for one run.
            raise ValueError(
                f"StagewiseDriver executes a flat sync round; "
                f"topology={tcfg.topology!r} is only supported by the "
                f"simulator backend (core.simulate.run)")
        self.net = NetworkModel(latency_s=tcfg.comm_latency_s,
                                bandwidth_gbps=tcfg.comm_bandwidth_gbps)
        self.algorithm = get_algorithm(tcfg.algo)
        policy = self.algorithm.sync_policy
        if getattr(policy, "asynchronous", False):
            # the driver's (train_step, sync_step) contract is a barriered
            # fixed-schedule round; running these policies here would
            # silently execute the wrong semantics under the right name
            raise ValueError(
                f"StagewiseDriver runs barriered fixed-schedule rounds, but "
                f"algorithm {self.algorithm.name!r} carries the asynchronous "
                f"{type(policy).__name__} policy (merge-on-arrival, no "
                f"barrier). Run it on the event runtime instead: "
                f"repro.runtime.run / repro.runtime.EventBackend")
        if getattr(policy, "adaptive", False):
            raise ValueError(
                f"StagewiseDriver runs barriered fixed-schedule rounds, but "
                f"algorithm {self.algorithm.name!r} carries the "
                f"{type(policy).__name__} policy, whose divergence probe "
                f"decides each round at runtime. Run it on the vmapped "
                f"simulator (core.simulate.run) or the event runtime "
                f"(repro.runtime.EventBackend)")
        self.stages = self.algorithm.stages(tcfg)

    def run(self, state: dict, batches, max_iters: Optional[int] = None
            ) -> DriverState:
        ds = DriverState(state=state)
        # a fresh Engine per run: its report is the run's comm ledger.
        # Streaming rounds price identically to Star (same bytes, same
        # serial α–β time) but additionally carry the per-leaf ledger.
        topo_cls = StreamingStar if self.streaming else Star
        engine = Engine(self.algorithm, self.tcfg,
                        topology=topo_cls(reducer=self.reducer,
                                          network=self.net))
        ds = engine.run(DriverBackend(self, ds, batches, max_iters))
        log.info("comm: reducer=%s rounds=%d bytes=%.3e modeled_time=%.3fs",
                 self.reducer.name, ds.rounds_total, ds.comm_bytes_total,
                 ds.comm_time_s)
        return ds
