"""STL-SGD stagewise driver for the distributed trainer.

Orchestrates Algorithms 2/3 over (train_step_local, sync_step) pairs built by
``core.local_sgd``: per stage s it fixes η_s, runs T_s local iterations and
triggers the parameter-averaging round every ⌊k_s⌋ steps; for the ^nc variants
the loss is the prox surrogate f^γ centered at the stage-start average.

The driver is step-function-agnostic — the tests drive it with tiny CPU
models, the launcher with pjit'd multi-pod steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.comm import NetworkModel, get_reducer, round_bytes, round_time
from repro.configs.base import TrainConfig
from repro.core import schedules as sched
from repro.utils.tree import tree_mean_leading
from repro.utils.logging import get_logger

log = get_logger("stl_sgd")


@dataclass
class StageResult:
    stage: int
    eta: float
    k: int
    iters: int
    rounds: int
    mean_loss: float


@dataclass
class DriverState:
    state: dict                 # {"params","opt","step"} with client axis
    center: Optional[dict] = None  # prox center (^nc)
    results: List[StageResult] = field(default_factory=list)
    rounds_total: int = 0
    iters_total: int = 0
    comm_bytes_total: int = 0      # modeled bytes moved by sync rounds
    comm_time_s: float = 0.0       # α–β modeled wall-clock of those rounds


class StagewiseDriver:
    """Runs cfg.algo over a stream of batches.

    train_step(state, batch, eta[, center]) -> (state, metrics)
    sync_step(state) -> state
    """

    def __init__(self, tcfg: TrainConfig, train_step: Callable,
                 sync_step: Callable, uses_center: bool = False,
                 reducer=None):
        self.tcfg = tcfg
        self.train_step = train_step
        self.sync_step = sync_step
        self.uses_center = uses_center
        # Comm accounting reducer, in priority order: explicit arg > the
        # reducer the sync_step itself was built with (local_sgd.
        # build_sync_step tags it, surviving jax.jit via __wrapped__) >
        # tcfg.reducer. The tag keeps accounting from silently diverging
        # from what the round actually transmits.
        if reducer is None:
            reducer = getattr(sync_step, "reducer", None) or getattr(
                getattr(sync_step, "__wrapped__", None), "reducer", None)
        self.reducer = get_reducer(
            reducer if reducer is not None else tcfg.reducer,
            quant_bits=tcfg.quant_bits, topk_frac=tcfg.topk_frac)
        self.net = NetworkModel(latency_s=tcfg.comm_latency_s,
                                bandwidth_gbps=tcfg.comm_bandwidth_gbps)
        self.stages = sched.make_stages(
            tcfg.algo, tcfg.eta1, tcfg.T1, tcfg.k1, tcfg.n_stages, tcfg.iid)

    def run(self, state: dict, batches, max_iters: Optional[int] = None
            ) -> DriverState:
        ds = DriverState(state=state)
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            state["params"])
        n_clients = jax.tree.leaves(state["params"])[0].shape[0]
        bytes_per_round = round_bytes(self.reducer, template, n_clients,
                                      self.net)
        time_per_round = round_time(self.net, bytes_per_round)
        it = iter(batches)
        for stage in self.stages:
            if self.uses_center:
                ds.center = tree_mean_leading(ds.state["params"])
            losses = []
            rounds = 0
            done = 0
            while done < stage.T:
                burst = min(stage.k, stage.T - done)
                for _ in range(burst):
                    batch = next(it)
                    if self.uses_center:
                        ds.state, m = self.train_step(ds.state, batch, stage.eta,
                                                      ds.center)
                    else:
                        ds.state, m = self.train_step(ds.state, batch, stage.eta)
                    losses.append(float(m["loss"]))
                    done += 1
                    ds.iters_total += 1
                    if max_iters and ds.iters_total >= max_iters:
                        break
                ds.state = self.sync_step(ds.state)
                rounds += 1
                ds.rounds_total += 1
                ds.comm_bytes_total += bytes_per_round
                ds.comm_time_s += time_per_round
                if max_iters and ds.iters_total >= max_iters:
                    break
            res = StageResult(stage.s, stage.eta, stage.k, done, rounds,
                              float(jnp.mean(jnp.asarray(losses))) if losses else float("nan"))
            ds.results.append(res)
            log.info("stage %d: eta=%.3g k=%d iters=%d rounds=%d loss=%.4f",
                     res.stage, res.eta, res.k, res.iters, res.rounds, res.mean_loss)
            if max_iters and ds.iters_total >= max_iters:
                break
        log.info("comm: reducer=%s rounds=%d bytes=%.3e modeled_time=%.3fs",
                 self.reducer.name, ds.rounds_total, ds.comm_bytes_total,
                 ds.comm_time_s)
        return ds
