from repro.data.partition import partition_paper, partition_iid
from repro.data.synthetic import (
    make_binary_classification,
    make_multiclass_images,
    make_token_stream,
)

__all__ = [
    "partition_paper",
    "partition_iid",
    "make_binary_classification",
    "make_multiclass_images",
    "make_token_stream",
]
