"""Client data partitioners.

``partition_paper`` reproduces the paper's §5 Non-IID construction: take s%
of the data i.i.d. and split it equally across clients; sort the remaining
(100−s)% by class label and deal it out to clients in order, so class
distributions differ sharply across clients. s=50 for the convex experiments,
s=0 for the non-convex ones.
"""
from __future__ import annotations

import numpy as np


def partition_iid(x, y, n_clients: int, seed: int = 0):
    """Random equal split. Returns dict with leading client axis."""
    rng = np.random.RandomState(seed)
    n = len(y)
    per = n // n_clients
    idx = rng.permutation(n)[: per * n_clients].reshape(n_clients, per)
    return {"x": np.asarray(x)[idx], "y": np.asarray(y)[idx]}


def partition_paper(x, y, n_clients: int, iid_percent: float, seed: int = 0):
    """The paper's split: iid_percent% random + rest label-sorted, dealt in order."""
    rng = np.random.RandomState(seed)
    x, y = np.asarray(x), np.asarray(y)
    n = len(y)
    per = n // n_clients
    usable = per * n_clients
    perm = rng.permutation(n)[:usable]
    n_iid = int(usable * iid_percent / 100.0)
    n_iid -= n_iid % n_clients  # keep equal shares
    iid_idx = perm[:n_iid]
    rest = perm[n_iid:]
    rest = rest[np.argsort(y[rest], kind="stable")]  # label-sorted block

    iid_shares = iid_idx.reshape(n_clients, -1) if n_iid else np.zeros((n_clients, 0), int)
    rest_shares = rest.reshape(n_clients, -1)
    idx = np.concatenate([iid_shares, rest_shares], axis=1)
    return {"x": x[idx], "y": y[idx]}


def gradient_diversity(client_data, grad_fn, params):
    """ζ measurement helper: (1/N) Σ ||∇f_i(x) − ∇f(x)||² at given params."""
    import jax
    import jax.numpy as jnp

    grads = jax.vmap(lambda d: grad_fn(params, d))(client_data)
    mean_g = jax.tree.map(lambda g: jnp.mean(g, 0), grads)
    sq = sum(
        jnp.sum(jnp.square(g - m[None]))
        for g, m in zip(jax.tree.leaves(grads), jax.tree.leaves(mean_g))
    )
    n = jax.tree.leaves(grads)[0].shape[0]
    return sq / n
