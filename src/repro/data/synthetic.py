"""Synthetic datasets (offline container — no downloads).

``make_binary_classification`` mimics the paper's a9a / MNIST-binary setup
(linearly-separable-ish sparse features, labels in {−1, +1});
``make_multiclass_images`` mimics CIFAR-10 (32×32×3, 10 classes) for the
non-convex experiments; ``make_token_stream`` produces LM token shards with
per-client Zipf skew for Non-IID language-model training.
"""
from __future__ import annotations

import numpy as np


def make_binary_classification(n: int = 32561, d: int = 123, seed: int = 0,
                               noise: float = 0.4, sparsity: float = 0.9):
    """a9a-like: sparse binary-ish features, {-1,+1} labels from a noisy halfspace."""
    rng = np.random.RandomState(seed)
    x = (rng.rand(n, d) > sparsity).astype(np.float32)
    x *= rng.rand(n, d).astype(np.float32) + 0.5
    w_true = rng.randn(d).astype(np.float32)
    margin = x @ w_true + noise * rng.randn(n).astype(np.float32)
    y = np.where(margin > np.median(margin), 1.0, -1.0).astype(np.float32)
    return x, y


def make_multiclass_images(n: int = 10000, n_classes: int = 10, hw: int = 32,
                           seed: int = 0):
    """CIFAR-like: class-conditional Gaussian blobs + structured noise."""
    rng = np.random.RandomState(seed)
    y = rng.randint(0, n_classes, size=n)
    protos = rng.randn(n_classes, hw, hw, 3).astype(np.float32)
    x = 0.6 * protos[y] + 0.8 * rng.randn(n, hw, hw, 3).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)


def make_token_stream(n_tokens: int, vocab: int, n_clients: int, seed: int = 0,
                      non_iid: bool = False):
    """Token shards (n_clients, n_tokens) — Zipf-ish unigram LM data.

    Non-IID: each client samples from a different random permutation of the
    Zipf distribution (distinct head vocabulary per client).
    """
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab + 1)
    base_p = 1.0 / ranks
    base_p /= base_p.sum()
    shards = []
    for c in range(n_clients):
        p = base_p if not non_iid else base_p[rng.permutation(vocab)]
        shards.append(rng.choice(vocab, size=n_tokens, p=p))
    return np.stack(shards).astype(np.int32)


def batch_iterator(tokens, batch: int, seq_len: int, seed: int = 0):
    """Yield (tokens, labels) windows from a flat token shard."""
    rng = np.random.RandomState(seed)
    n = tokens.shape[-1] - seq_len - 1
    while True:
        starts = rng.randint(0, n, size=batch)
        xs = np.stack([tokens[..., s : s + seq_len] for s in starts])
        ys = np.stack([tokens[..., s + 1 : s + seq_len + 1] for s in starts])
        yield xs, ys
