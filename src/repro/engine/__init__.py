# First-class Algorithm/SyncPolicy/Topology API. An Algorithm bundles a
# SyncPolicy (when to communicate: the stagewise η_s/T_s/k_s schedules and
# prox-center policy) with a LocalUpdate (how clients step: plain SGD,
# large-batch, growing-batch); a Topology routes the round's bytes (flat
# star or hierarchical pod/WAN) with per-hop α–β pricing; the Engine drives
# any registered algorithm through either execution backend (vmapped
# simulator / pjit stagewise driver) over one shared stage stream.
from repro.engine.algorithm import (
    Algorithm,
    algorithm_names,
    get_algorithm,
    make_async,
    register,
)
from repro.engine.engine import Engine, EngineReport, StageStatus, topology_for
from repro.engine.policy import (
    AdaptivePeriod,
    AsyncPeriod,
    EveryStep,
    FixedPeriod,
    Stage,
    StagewiseGeometric,
    StagewiseLinear,
    SyncPolicy,
)
from repro.engine.topology import (
    Hierarchical,
    HopCost,
    LeafCost,
    Star,
    StreamingStar,
    Topology,
    get_topology,
)
from repro.engine.update import (
    GrowingBatchUpdate,
    LargeBatchUpdate,
    LocalUpdate,
    SgdUpdate,
)

__all__ = [
    "AdaptivePeriod",
    "Algorithm",
    "AsyncPeriod",
    "Engine",
    "EngineReport",
    "EveryStep",
    "FixedPeriod",
    "GrowingBatchUpdate",
    "Hierarchical",
    "HopCost",
    "LargeBatchUpdate",
    "LeafCost",
    "LocalUpdate",
    "SgdUpdate",
    "Stage",
    "StageStatus",
    "StagewiseGeometric",
    "StagewiseLinear",
    "Star",
    "StreamingStar",
    "SyncPolicy",
    "Topology",
    "algorithm_names",
    "get_algorithm",
    "get_topology",
    "make_async",
    "register",
    "topology_for",
]
