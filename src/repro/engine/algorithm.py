"""Algorithm = SyncPolicy × LocalUpdate × prox flag, plus the registry.

An ``Algorithm`` is the declarative description of one training method:
*when* to communicate (SyncPolicy), *how* each client steps between rounds
(LocalUpdate), and whether the loss is the ^nc prox surrogate re-centered
per stage. Both execution backends (the vmapped simulator and the pjit
stagewise driver) consume Algorithms — no string dispatch survives below
this layer.

The registry keeps the seven paper names working everywhere a config or CLI
says ``algo="stl_sc"``:

  sync     SyncSGD                      EveryStep            + SgdUpdate
  lb       Large-batch SyncSGD          EveryStep            + LargeBatch
  crpsgd   CR-PSGD [38]                 EveryStep            + GrowingBatch
  local    Local SGD (Alg. 1)           FixedPeriod          + SgdUpdate
  stl_sc   STL-SGD^sc (Alg. 2)          StagewiseGeometric   + SgdUpdate
  stl_nc1  STL-SGD^nc Opt. 1 (Alg. 3)   StagewiseGeometric*  + SgdUpdate
  stl_nc2  STL-SGD^nc Opt. 2 (Alg. 3)   StagewiseLinear*     + SgdUpdate
                                        (* prox, re-centered per stage)

``register`` is open: new methods plug in without touching the engine or
any front-end. Two registry extensions ship with the runtime subsystem:

  adaptive  divergence-triggered periods    AdaptivePeriod(StagewiseGeo)
  <name>+async  any registered name wrapped in AsyncPeriod (barrier-free
                merge-on-arrival rounds; executed by repro.runtime)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.engine.policy import (
    AdaptivePeriod,
    AsyncPeriod,
    EveryStep,
    FixedPeriod,
    Stage,
    StagewiseGeometric,
    StagewiseLinear,
    SyncPolicy,
)
from repro.engine.update import (
    GrowingBatchUpdate,
    LargeBatchUpdate,
    LocalUpdate,
    SgdUpdate,
)


@dataclass(frozen=True)
class Algorithm:
    """One training method, declaratively: *when* to communicate
    (``sync_policy`` — the (η_s, T_s, k_s) stage schedule, T_s in local
    iterations, k_s in local steps between rounds), *how* clients step
    between rounds (``local_update`` — the minibatch size/growth rule),
    and whether the loss is the ^nc prox surrogate f^γ re-centered at
    each stage start (``prox``, active only when cfg.gamma_inv > 0).
    Resolved by name through the registry (``get_algorithm``); consumed
    unchanged by all three execution backends."""

    name: str
    sync_policy: SyncPolicy
    local_update: LocalUpdate = field(default_factory=SgdUpdate)
    # ^nc prox surrogate f^γ — active only when cfg.gamma_inv > 0
    prox: bool = False

    def stages(self, cfg) -> List[Stage]:
        """Concrete (η_s, T_s, k_s) stage list for a TrainConfig."""
        return self.sync_policy.stages(cfg.eta1, cfg.T1, cfg.k1,
                                       cfg.n_stages, cfg.iid)

    def uses_center(self, cfg) -> bool:
        """Whether runs re-center a prox term at each stage start."""
        return self.prox and cfg.gamma_inv > 0.0

    def gamma_inv(self, cfg) -> float:
        """Effective prox strength 1/γ (0.0 when the method has no prox
        term or the config disables it)."""
        return cfg.gamma_inv if self.uses_center(cfg) else 0.0


_REGISTRY: Dict[str, Algorithm] = {}


def register(algorithm: Algorithm, *, overwrite: bool = False) -> Algorithm:
    """Add an Algorithm to the registry under its ``name``.

    Every front-end (simulator, driver, runtime, benchmarks, CLI) resolves
    ``cfg.algo`` strings through this registry, so a registered method is
    immediately runnable everywhere — no engine or front-end edits. Raises
    on duplicate names unless ``overwrite=True``; returns the algorithm
    for decorator-style use.
    """
    if algorithm.name in _REGISTRY and not overwrite:
        raise ValueError(f"algorithm {algorithm.name!r} already registered")
    _REGISTRY[algorithm.name] = algorithm
    return algorithm


def get_algorithm(name) -> Algorithm:
    """Resolve an algorithm by registry name (Algorithm passes through).

    Any registered name composes with barrier-free merging via the
    ``"<name>+async"`` suffix — e.g. ``get_algorithm("stl_sc+async")`` wraps
    STL-SGD^sc's schedule in an ``AsyncPeriod`` policy (see ``make_async``).
    """
    if isinstance(name, Algorithm):
        return name
    if isinstance(name, str) and name.endswith("+async"):
        return make_async(get_algorithm(name[: -len("+async")]))
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm: {name!r} (known: {algorithm_names()})"
        ) from None


def make_async(algorithm) -> Algorithm:
    """Wrap an Algorithm's SyncPolicy in ``AsyncPeriod`` (idempotent).

    The schedule, local update and prox flag are preserved; only the round
    semantics change from barriered average to merge-on-arrival. Executable
    by ``repro.runtime.EventBackend`` only.
    """
    algo = get_algorithm(algorithm)
    if algo.sync_policy.asynchronous:
        return algo
    return Algorithm(name=f"{algo.name}+async",
                     sync_policy=AsyncPeriod(base=algo.sync_policy,
                                             recenter=algo.sync_policy.recenter),
                     local_update=algo.local_update, prox=algo.prox)


def algorithm_names() -> Tuple[str, ...]:
    """Registered algorithm names, in registration order — the exact
    strings ``TrainConfig.algo`` accepts (each also composes with the
    ``"+async"`` suffix for barrier-free execution)."""
    return tuple(_REGISTRY)


register(Algorithm("sync", EveryStep()))
register(Algorithm("lb", EveryStep(), LargeBatchUpdate()))
register(Algorithm("crpsgd", EveryStep(), GrowingBatchUpdate()))
register(Algorithm("local", FixedPeriod()))
register(Algorithm("stl_sc", StagewiseGeometric()))
register(Algorithm("stl_nc1", StagewiseGeometric(recenter=True), prox=True))
register(Algorithm("stl_nc2", StagewiseLinear(recenter=True), prox=True))
# divergence-triggered periods: stl_sc's η_s/T_s schedule, k_s chosen at
# runtime by the replica-divergence probe (cap = the geometric k_s)
register(Algorithm("adaptive", AdaptivePeriod()))
