"""Engine — one stage-stream driver for every execution backend.

``Engine.run(backend)`` walks the Algorithm's stage stream (the SyncPolicy's
(η_s, T_s, k_s) schedule) and delegates stage execution to a *backend*:

  * ``core.simulate.VmapSimulatorBackend`` — N vmapped client replicas on
    one host (the paper-fidelity convergence engine);
  * ``core.stl_sgd.DriverBackend`` — pjit step functions over a mesh client
    axis (the production trainer). Accepts every topology, including
    ``topology="hierarchical"``: the driver's two-level sync step executes
    the same ``Hierarchical.reduce`` the simulator runs, and the per-round
    / per-(leaf, hop) ledger below prices exactly those two hops.

Both front-ends therefore provably run the same schedule, the same
prox-center policy, and the same topology-priced communication accounting —
the engine owns the per-round byte/time ledger via its Topology, so
"rounds × bytes × modeled seconds" is computed once, identically, for
simulator traces and distributed runs.

Backend contract (duck-typed, see ``StageStatus``):

  setup(engine)               — allocate state; call
                                ``engine.set_cost_basis(template, n)`` so
                                the ledger can price rounds.
  run_stage(stage, engine) -> StageStatus
                              — run one stage (or a prefix of it, if a
                                target/budget stops the run early).
  finish(engine) -> result    — the front-end's native return value.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

from repro.comm.cost import NetworkModel
from repro.engine.algorithm import Algorithm, get_algorithm
from repro.engine.topology import Topology, get_topology


@dataclass
class StageStatus:
    """What a backend did with one stage: ``rounds`` communication rounds
    executed and ``iters`` local iterations consumed (the engine scales
    both into the comm ledger), plus the early-exit flag."""

    rounds: int = 0
    iters: int = 0
    stop: bool = False   # target hit / budget exhausted — end the run


@dataclass
class EngineReport:
    """Cross-backend run ledger.

    Units: ``rounds_total`` / ``iters_total`` count communication rounds
    and local iterations; ``comm_bytes_total`` is modeled payload bytes
    moved by those rounds (all hops); ``comm_time_s`` their serial α–β
    link time in modeled seconds. ``hop_costs`` is the per-hop price of
    one round (``topology.HopCost``); ``leaf_costs`` the per-(leaf, hop)
    breakdown of the same round (``topology.LeafCost``, empty when the
    topology has no per-leaf accounting) — multiply by ``rounds_total``
    for run totals; the sums reconcile with the tree-level ledger.
    """

    rounds_total: int = 0
    iters_total: int = 0
    comm_bytes_total: int = 0
    comm_time_s: float = 0.0
    stages_run: int = 0
    hop_costs: List[Any] = field(default_factory=list)
    leaf_costs: List[Any] = field(default_factory=list)


def topology_for(cfg, reducer=None, topology=None) -> Topology:
    """Resolve a Topology from a TrainConfig's comm fields.

    Priority: explicit ``topology`` arg > cfg.topology string. The reducer
    (explicit arg > cfg.reducer) becomes the Star uplink reducer, or the
    intra-pod reducer of a hierarchical topology (whose inter-pod reducer
    comes from cfg.inter_reducer).
    """
    if isinstance(topology, Topology):
        return topology
    net = NetworkModel(latency_s=cfg.comm_latency_s,
                       bandwidth_gbps=cfg.comm_bandwidth_gbps)
    return get_topology(
        topology if topology is not None else getattr(cfg, "topology", "star"),
        reducer=reducer if reducer is not None else cfg.reducer,
        network=net, n_pods=getattr(cfg, "n_pods", 2),
        inter_reducer=getattr(cfg, "inter_reducer", "int8"),
        quant_bits=cfg.quant_bits, topk_frac=cfg.topk_frac)


class Engine:
    """Drives one Algorithm over one Topology through one backend."""

    def __init__(self, algorithm, cfg, topology=None, reducer=None):
        self.algorithm: Algorithm = get_algorithm(algorithm)
        self.cfg = cfg
        self.topology: Topology = topology_for(cfg, reducer=reducer,
                                               topology=topology)
        self.stages = self.algorithm.stages(cfg)
        self.report = EngineReport()
        self._bytes_per_round: Optional[int] = None
        self._time_per_round: Optional[float] = None

    # -- comm-cost ledger ---------------------------------------------------

    def set_cost_basis(self, template, n_clients: int):
        """Price one round for this run (template = single-replica pytree).

        Fills both ledger views: the per-hop tree-level costs and — when
        the topology supports it — the per-(leaf, hop) breakdown used by
        streaming rounds. Bytes are modeled payload bytes, times modeled
        seconds on the serial α–β link.
        """
        self._template = template
        self._n_clients = n_clients
        hops = self.topology.hop_costs(template, n_clients)
        self.report.hop_costs = hops
        self.report.leaf_costs = self.topology.leaf_costs(template, n_clients)
        self._bytes_per_round = sum(h.bytes for h in hops)
        self._time_per_round = sum(h.time_s for h in hops)

    def leaf_ledger(self) -> List[dict]:
        """Per-leaf comm totals for the rounds run so far.

        One dict per (leaf, hop): ``bytes`` (modeled payload bytes) and
        ``time_s`` (serial α–β seconds), each the per-round ``LeafCost``
        scaled by ``rounds_total``. Summing the entries reconciles with
        ``comm_bytes_total`` bit-exactly and ``comm_time_s`` to float-sum
        precision. Empty when the topology has no per-leaf accounting.
        """
        r = self.report.rounds_total
        return [{"leaf": lc.leaf, "path": lc.path, "hop": lc.hop,
                 "bytes": lc.bytes * r, "time_s": lc.time_s * r}
                for lc in self.report.leaf_costs]

    def comm_summary(self) -> dict:
        """Per-hop comm report for the rounds run so far."""
        return self.topology.summary(self._template, self._n_clients,
                                     self.report.rounds_total)

    # -- run loop -----------------------------------------------------------

    def run(self, backend):
        """Walk the stage stream through ``backend`` and return its native
        result, accumulating the run ledger (rounds, iterations, modeled
        comm bytes/seconds) in ``self.report`` along the way."""
        backend.setup(self)
        if self._bytes_per_round is None:
            raise RuntimeError(
                "backend.setup() must call engine.set_cost_basis()")
        for stage in self.stages:
            status = backend.run_stage(stage, self)
            self.report.stages_run += 1
            self.report.rounds_total += status.rounds
            self.report.iters_total += status.iters
            self.report.comm_bytes_total += status.rounds * self._bytes_per_round
            self.report.comm_time_s += status.rounds * self._time_per_round
            if status.stop:
                break
        return backend.finish(self)
