"""Engine — one stage-stream driver for every execution backend.

``Engine.run(backend)`` walks the Algorithm's stage stream (the SyncPolicy's
(η_s, T_s, k_s) schedule) and delegates stage execution to a *backend*:

  * ``core.simulate.VmapSimulatorBackend`` — N vmapped client replicas on
    one host (the paper-fidelity convergence engine);
  * ``core.stl_sgd.DriverBackend`` — pjit step functions over a mesh client
    axis (the production trainer). Accepts every topology, including
    ``topology="hierarchical"``: the driver's two-level sync step executes
    the same ``Hierarchical.reduce`` the simulator runs, and the per-round
    / per-(leaf, hop) ledger below prices exactly those two hops.

Both front-ends therefore provably run the same schedule, the same
prox-center policy, and the same topology-priced communication accounting —
the engine owns the per-round byte/time ledger via its Topology, so
"rounds × bytes × modeled seconds" is computed once, identically, for
simulator traces and distributed runs.

Backend contract (duck-typed, see ``StageStatus``):

  setup(engine)               — allocate state; call
                                ``engine.set_cost_basis(template, n)`` so
                                the ledger can price rounds.
  run_stage(stage, engine) -> StageStatus
                              — run one stage (or a prefix of it, if a
                                target/budget stops the run early).
  finish(engine) -> result    — the front-end's native return value.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

from repro.comm.cost import NetworkModel
from repro.engine.algorithm import Algorithm, get_algorithm
from repro.engine.topology import Topology, get_topology
from repro.obs import metrics as obs_metrics
from repro.obs import series as obs_series
from repro.obs.trace import CAT_COMM, CAT_CONTROL, MODELED, NULL_TRACER
from repro.utils.logging import get_logger

log = get_logger("engine")


@dataclass
class StageStatus:
    """What a backend did with one stage: ``rounds`` communication rounds
    executed and ``iters`` local iterations consumed (the engine scales
    both into the comm ledger), plus the early-exit flag."""

    rounds: int = 0
    iters: int = 0
    stop: bool = False   # target hit / budget exhausted — end the run


@dataclass
class EngineReport:
    """Cross-backend run ledger.

    Units: ``rounds_total`` / ``iters_total`` count communication rounds
    and local iterations; ``comm_bytes_total`` is modeled payload bytes
    moved by those rounds (all hops); ``comm_time_s`` their serial α–β
    link time in modeled seconds. ``hop_costs`` is the per-hop price of
    one round (``topology.HopCost``); ``leaf_costs`` the per-(leaf, hop)
    breakdown of the same round (``topology.LeafCost``, empty when the
    topology has no per-leaf accounting) — multiply by ``rounds_total``
    for run totals; the sums reconcile with the tree-level ledger.
    """

    rounds_total: int = 0
    iters_total: int = 0
    comm_bytes_total: int = 0
    comm_time_s: float = 0.0
    stages_run: int = 0
    hop_costs: List[Any] = field(default_factory=list)
    leaf_costs: List[Any] = field(default_factory=list)
    # obs.metrics / obs.series registry snapshots taken at run end
    metrics: dict = field(default_factory=dict)
    series: dict = field(default_factory=dict)


def topology_for(cfg, reducer=None, topology=None) -> Topology:
    """Resolve a Topology from a TrainConfig's comm fields.

    Priority: explicit ``topology`` arg > cfg.topology string. The reducer
    (explicit arg > cfg.reducer) becomes the Star uplink reducer, or the
    intra-pod reducer of a hierarchical topology (whose inter-pod reducer
    comes from cfg.inter_reducer).
    """
    if isinstance(topology, Topology):
        return topology
    net = NetworkModel(latency_s=cfg.comm_latency_s,
                       bandwidth_gbps=cfg.comm_bandwidth_gbps,
                       count_downlink=getattr(cfg, "count_downlink", False))
    return get_topology(
        topology if topology is not None else getattr(cfg, "topology", "star"),
        reducer=reducer if reducer is not None else cfg.reducer,
        network=net, n_pods=getattr(cfg, "n_pods", 2),
        inter_reducer=getattr(cfg, "inter_reducer", "int8"),
        quant_bits=cfg.quant_bits, topk_frac=cfg.topk_frac)


class Engine:
    """Drives one Algorithm over one Topology through one backend."""

    def __init__(self, algorithm, cfg, topology=None, reducer=None,
                 tracer=None, series=None):
        self.algorithm: Algorithm = get_algorithm(algorithm)
        self.cfg = cfg
        self.topology: Topology = topology_for(cfg, reducer=reducer,
                                               topology=topology)
        self.stages = self.algorithm.stages(cfg)
        self.report = EngineReport()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = obs_metrics.registry()
        self.series: obs_series.SeriesRegistry = (
            series if series is not None else obs_series.registry())
        self._bytes_per_round: Optional[int] = None
        self._time_per_round: Optional[float] = None
        self._modeled_t = 0.0   # cursor of the modeled α–β span timeline
        self._cum_bytes = 0     # modeled payload bytes up to the cursor

    # -- comm-cost ledger ---------------------------------------------------

    def set_cost_basis(self, template, n_clients: int):
        """Price one round for this run (template = single-replica pytree).

        Fills both ledger views: the per-hop tree-level costs and — when
        the topology supports it — the per-(leaf, hop) breakdown used by
        streaming rounds. Bytes are modeled payload bytes, times modeled
        seconds on the serial α–β link.
        """
        self._template = template
        self._n_clients = n_clients
        hops = self.topology.hop_costs(template, n_clients)
        self.report.hop_costs = hops
        self.report.leaf_costs = self.topology.leaf_costs(template, n_clients)
        self._bytes_per_round = sum(h.bytes for h in hops)
        self._time_per_round = sum(h.time_s for h in hops)

    def leaf_ledger(self) -> List[dict]:
        """Per-leaf comm totals for the rounds run so far.

        One dict per (leaf, hop): ``bytes`` (modeled payload bytes) and
        ``time_s`` (serial α–β seconds), each the per-round ``LeafCost``
        scaled by ``rounds_total``. Summing the entries reconciles with
        ``comm_bytes_total`` bit-exactly and ``comm_time_s`` to float-sum
        precision. Empty when the topology has no per-leaf accounting.
        """
        r = self.report.rounds_total
        return [{"leaf": lc.leaf, "path": lc.path, "hop": lc.hop,
                 "bytes": lc.bytes * r, "time_s": lc.time_s * r}
                for lc in self.report.leaf_costs]

    def comm_summary(self) -> dict:
        """Per-hop comm report for the rounds run so far."""
        return self.topology.summary(self._template, self._n_clients,
                                     self.report.rounds_total)

    # -- observability ------------------------------------------------------

    def _modeled_series(self, name: str, unit: str, help: str):
        return self.series.series(name, clock=MODELED, unit=unit, help=help)

    def trace_rounds(self, stage, rounds: int):
        """Advance the modeled α–β timeline by ``rounds`` rounds of
        ``stage``, emitting per-round series and — when a tracer is
        attached — round spans.

        The cursor arithmetic (per-hop sequential adds) is one code path
        whether or not spans are emitted, so the modeled timestamps on
        the ``comm.*`` series are bit-identical between traced and
        untraced runs and align exactly with the span end times.

        Each traced round lays its hops sequentially (``round`` >
        ``reduce[hop]`` > ``reduce_leaf[leaf]`` > ``broadcast`` marker),
        so summing the ``bytes`` attributes of all ``reduce_leaf`` spans
        reconciles bit-exactly with ``Engine.leaf_ledger()`` — both are
        ``rounds × LeafCost.bytes``.
        """
        if rounds <= 0:
            return
        tracer = self.tracer
        s_bytes = self._modeled_series(
            "comm.round_bytes", "B", "modeled payload bytes of each round")
        s_time = self._modeled_series(
            "comm.round_time_s", "s",
            "modeled serial α–β link seconds of each round")
        s_cum = self._modeled_series(
            "comm.cum_bytes", "B",
            "cumulative modeled payload bytes at each round boundary")
        leaf_by_hop: dict = {}
        if tracer:
            for lc in self.report.leaf_costs:
                leaf_by_hop.setdefault(lc.hop, []).append(lc)
        for r in range(rounds):
            t = self._modeled_t
            if tracer:
                rid = tracer.begin("round", t, cat=CAT_CONTROL,
                                   track="round", clock=MODELED,
                                   attrs={"s": stage.s, "eta": stage.eta,
                                          "k": stage.k})
            hop_t = t
            for hop in self.report.hop_costs:
                if tracer:
                    hid = tracer.begin(
                        "reduce", hop_t, cat=CAT_COMM,
                        track=f"hop/{hop.hop}", clock=MODELED,
                        attrs={"hop": hop.hop, "reducer": hop.reducer,
                               "bytes": hop.bytes, "time_s": hop.time_s})
                    leaf_t = hop_t
                    for lc in leaf_by_hop.get(hop.hop, ()):
                        tracer.add(
                            "reduce_leaf", leaf_t, leaf_t + lc.time_s,
                            cat=CAT_COMM, track=f"leaf/{lc.leaf}",
                            clock=MODELED,
                            attrs={"leaf": lc.leaf, "path": lc.path,
                                   "hop": lc.hop, "bytes": lc.bytes,
                                   "time_s": lc.time_s})
                        leaf_t += lc.time_s
                hop_t += hop.time_s
                if tracer:
                    tracer.end(hid, hop_t)
            if tracer:
                tracer.instant("broadcast", hop_t, cat=CAT_COMM,
                               track="round", clock=MODELED,
                               attrs={"s": stage.s})
                tracer.end(rid, hop_t)
            self._modeled_t = hop_t
            self._cum_bytes += self._bytes_per_round or 0
            s_bytes.record(hop_t, float(self._bytes_per_round or 0))
            s_time.record(hop_t, hop_t - t)
            s_cum.record(hop_t, float(self._cum_bytes))

    def _count_stage(self, stage, status):
        """Report one stage's ledger into the obs.metrics registry."""
        m = self.metrics
        m.counter("engine.rounds", unit="rounds",
                  help="communication rounds executed").inc(status.rounds)
        m.counter("engine.iters", unit="iterations",
                  help="local iterations consumed").inc(status.iters)
        m.counter("engine.stages", unit="stages",
                  help="stages executed").inc()
        cb = m.counter("comm.bytes", unit="B",
                       help="modeled payload bytes by hop/reducer")
        ct = m.counter("comm.time_s", unit="s",
                       help="modeled serial α–β link seconds by hop/reducer")
        for hop in self.report.hop_costs:
            cb.inc(status.rounds * hop.bytes, hop=hop.hop,
                   reducer=hop.reducer)
            ct.inc(status.rounds * hop.time_s, hop=hop.hop,
                   reducer=hop.reducer)

    def _record_stage_series(self, stage):
        """Per-stage objective-vs-cumulative-bytes curve: at each stage
        boundary (the modeled cursor), sample the stage-end objective the
        backend published (``train.stage_objective`` gauge) against the
        bytes spent reaching it."""
        self._modeled_series(
            "train.stage_bytes", "B",
            "cumulative modeled payload bytes at each stage boundary"
        ).record(self._modeled_t, float(self._cum_bytes))
        if "train.stage_objective" in self.metrics:
            obj = self.metrics["train.stage_objective"].value(stage=stage.s)
            if obj is not None:
                self._modeled_series(
                    "train.stage_objective", "",
                    "stage-end objective at the modeled stage boundary"
                ).record(self._modeled_t, float(obj))

    # -- run loop -----------------------------------------------------------

    def run(self, backend):
        """Walk the stage stream through ``backend`` and return its native
        result, accumulating the run ledger (rounds, iterations, modeled
        comm bytes/seconds) in ``self.report`` along the way."""
        backend.setup(self)
        if self._bytes_per_round is None:
            raise RuntimeError(
                "backend.setup() must call engine.set_cost_basis()")
        run_attrs = {"algorithm": self.algorithm.name,
                     "topology": type(self.topology).__name__,
                     "backend": type(backend).__name__}
        with self.tracer.span("run", attrs=run_attrs):
            for stage in self.stages:
                with self.tracer.span(
                        "stage", attrs={"s": stage.s, "eta": stage.eta,
                                        "T": stage.T, "k": stage.k}) as sp:
                    status = backend.run_stage(stage, self)
                    sp.set(rounds=status.rounds, iters=status.iters)
                self.trace_rounds(stage, status.rounds)
                self._record_stage_series(stage)
                self.report.stages_run += 1
                self.report.rounds_total += status.rounds
                self.report.iters_total += status.iters
                self.report.comm_bytes_total += status.rounds * self._bytes_per_round
                self.report.comm_time_s += status.rounds * self._time_per_round
                self._count_stage(stage, status)
                log.debug("stage_done", s=stage.s, eta=stage.eta,
                          k=stage.k, rounds=status.rounds,
                          iters=status.iters, stop=status.stop)
                if status.stop:
                    break
            self.report.metrics = self.metrics.snapshot()
            self.report.series = self.series.snapshot()
        return backend.finish(self)
