"""SyncPolicy — *when* to communicate.

A sync policy owns the stagewise schedule (η_s, T_s, k_s) and the
prox-center policy (whether the stage start re-centers the ^nc prox
surrogate). This is the paper's actual contribution factored into one
object: Algorithms 2/3 differ from Local SGD *only* in their SyncPolicy.

  EveryStep            k ≡ 1                       (SyncSGD and its batch
                                                    variants)
  FixedPeriod          k ≡ k₁                      (Local SGD, Alg. 1)
  StagewiseGeometric   η/2, T×2, k×2 (IID) | ×√2   (Alg. 2 / Alg. 3 Opt. 1)
  StagewiseLinear      η/s, T×s, k×s (IID) | ×√s   (Alg. 3 Opt. 2)

Policies are pure: ``stages(eta1, T1, k1, n_stages, iid)`` expands to the
concrete ``Stage`` list both execution backends consume, so the vmapped
simulator and the pjit driver provably run the same schedule. ``Stage`` and
the k-growth arithmetic live here (re-exported by ``core.schedules`` for
compatibility) so the engine layer has no dependency on ``repro.core``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class Stage:
    """One stage of a stagewise schedule — the unit both execution
    backends consume. Units: ``T`` counts local iterations in the stage,
    ``k`` local steps between communication rounds (so the stage runs
    ⌈T/k⌉ rounds), ``eta`` is the stage learning rate η_s."""

    s: int          # 1-based stage index
    eta: float      # learning rate η_s
    T: int          # iterations in this stage
    k: int          # communication period (⌊k_s⌋, ≥ 1 — Alg. 2 line 2)
    k_raw: float    # un-floored k_s (the geometric/linear state variable)


def k_growth(iid: bool, geometric: bool, s: int) -> float:
    """Multiplier applied to k₁ (local steps per round) at stage s
    (1-based): 2^(s−1) / √2^(s−1) for the geometric schedules (Alg. 2 /
    Alg. 3 Opt. 1), s / √s for the linear one (Alg. 3 Opt. 2) — the IID
    variant in the numerator position, the Non-IID √ variant otherwise."""
    if geometric:
        return 2.0 ** (s - 1) if iid else math.sqrt(2.0) ** (s - 1)
    return float(s) if iid else math.sqrt(float(s))


@dataclass(frozen=True)
class SyncPolicy:
    """Base protocol. ``recenter`` is the prox-center policy: True means the
    prox surrogate re-centers at the averaged params at each stage start
    (Alg. 3); False means no center is ever produced.

    Two class-level capability flags route execution:
      ``asynchronous`` — rounds merge on arrival instead of barriering
        (honoured by ``repro.runtime.EventBackend``);
      ``adaptive`` — the k in each Stage is only a *cap*; the backend
        triggers a round when replica divergence crosses ``threshold``.
    """

    recenter: bool = False
    asynchronous = False  # class attribute, not a schedule parameter
    adaptive = False

    def stage(self, s: int, eta1: float, T1: int, k1: float,
              iid: bool) -> Stage:
        """Concrete stage s (1-based) from the initial (η₁, T₁, k₁) — η in
        learning-rate units, T in local iterations, k in steps/round."""
        raise NotImplementedError

    def stages(self, eta1: float, T1: int, k1: float, n_stages: int,
               iid: bool = True) -> List[Stage]:
        """Expand the full schedule both execution backends consume: the
        concrete Stage list for stages 1..n_stages."""
        return [self.stage(s, eta1, T1, k1, iid)
                for s in range(1, n_stages + 1)]


@dataclass(frozen=True)
class EveryStep(SyncPolicy):
    """k ≡ 1: communicate after every local step (SyncSGD / LB / CR-PSGD)."""

    def stage(self, s, eta1, T1, k1, iid):
        return Stage(s=s, eta=eta1, T=T1, k=1, k_raw=1.0)


@dataclass(frozen=True)
class FixedPeriod(SyncPolicy):
    """k ≡ k₁: Local SGD (Alg. 1) — identical stages, fixed period."""

    def stage(self, s, eta1, T1, k1, iid):
        return Stage(s=s, eta=eta1, T=T1, k=max(1, int(k1)), k_raw=k1)


@dataclass(frozen=True)
class StagewiseGeometric(SyncPolicy):
    """η_{s+1}=η_s/2, T_{s+1}=2T_s, k_{s+1}=2k_s (IID) or √2·k_s (Non-IID).

    Algorithm 2 (STL-SGD^sc) and Algorithm 3 Option 1 (with recenter=True).
    """

    def stage(self, s, eta1, T1, k1, iid):
        kr = k1 * k_growth(iid, True, s)
        return Stage(s=s, eta=eta1 / (2.0 ** (s - 1)), T=T1 * (2 ** (s - 1)),
                     k=max(1, int(kr)), k_raw=kr)


@dataclass(frozen=True)
class StagewiseLinear(SyncPolicy):
    """η_s=η₁/s, T_s=sT₁, k_s=sk₁ (IID) or √s·k₁ (Non-IID).

    Algorithm 3 Option 2 (STL-SGD^nc, linear growth).
    """

    def stage(self, s, eta1, T1, k1, iid):
        kr = k1 * k_growth(iid, False, s)
        return Stage(s=s, eta=eta1 / s, T=T1 * s,
                     k=max(1, int(kr)), k_raw=kr)


@dataclass(frozen=True)
class AsyncPeriod(SyncPolicy):
    """Barrier-free rounds: clients upload after k local steps *without*
    waiting for each other; the server merges each message on arrival with
    a staleness-decayed weight (``comm.StalenessWeightedMean``).

    The (η_s, T_s, k_s) schedule is delegated to ``base`` — any existing
    policy composes (``engine.make_async`` wraps a registered Algorithm), so
    e.g. STL-SGD's growing k_s runs with asynchronous merging unchanged.
    Only ``repro.runtime.EventBackend`` can execute the asynchronous
    semantics; the barrier backends reject it.
    """

    base: SyncPolicy = field(default_factory=FixedPeriod)
    asynchronous = True

    def stage(self, s, eta1, T1, k1, iid):
        return self.base.stage(s, eta1, T1, k1, iid)


@dataclass(frozen=True)
class AdaptivePeriod(SyncPolicy):
    """Divergence-triggered rounds (ROADMAP "adaptive/learned periods").

    η_s and T_s follow ``base``'s schedule; the Stage's k becomes a *cap*:
    between rounds the backend probes the replica divergence

        div = Σ_leaves mean_i ‖x_i − x̄‖² / (Σ_leaves ‖x̄‖² + ε)

    after every local step and triggers the communication round as soon as
    ``div ≥ threshold`` (or the cap is hit). Early stages sync often (large
    η ⇒ fast divergence); late stages stretch the period automatically —
    the data-driven analogue of the paper's hand-designed k_s growth.
    """

    base: SyncPolicy = field(default_factory=StagewiseGeometric)
    threshold: float = 3e-4
    adaptive = True

    def stage(self, s, eta1, T1, k1, iid):
        return self.base.stage(s, eta1, T1, k1, iid)
