"""SyncPolicy — *when* to communicate.

A sync policy owns the stagewise schedule (η_s, T_s, k_s) and the
prox-center policy (whether the stage start re-centers the ^nc prox
surrogate). This is the paper's actual contribution factored into one
object: Algorithms 2/3 differ from Local SGD *only* in their SyncPolicy.

  EveryStep            k ≡ 1                       (SyncSGD and its batch
                                                    variants)
  FixedPeriod          k ≡ k₁                      (Local SGD, Alg. 1)
  StagewiseGeometric   η/2, T×2, k×2 (IID) | ×√2   (Alg. 2 / Alg. 3 Opt. 1)
  StagewiseLinear      η/s, T×s, k×s (IID) | ×√s   (Alg. 3 Opt. 2)

Policies are pure: ``stages(eta1, T1, k1, n_stages, iid)`` expands to the
concrete ``Stage`` list both execution backends consume, so the vmapped
simulator and the pjit driver provably run the same schedule. ``Stage`` and
the k-growth arithmetic live here (re-exported by ``core.schedules`` for
compatibility) so the engine layer has no dependency on ``repro.core``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class Stage:
    s: int          # 1-based stage index
    eta: float      # learning rate η_s
    T: int          # iterations in this stage
    k: int          # communication period (⌊k_s⌋, ≥ 1 — Alg. 2 line 2)
    k_raw: float    # un-floored k_s (the geometric/linear state variable)


def k_growth(iid: bool, geometric: bool, s: int) -> float:
    """Multiplier applied to k₁ at stage s (1-based)."""
    if geometric:
        return 2.0 ** (s - 1) if iid else math.sqrt(2.0) ** (s - 1)
    return float(s) if iid else math.sqrt(float(s))


@dataclass(frozen=True)
class SyncPolicy:
    """Base protocol. ``recenter`` is the prox-center policy: True means the
    prox surrogate re-centers at the averaged params at each stage start
    (Alg. 3); False means no center is ever produced."""

    recenter: bool = False

    def stage(self, s: int, eta1: float, T1: int, k1: float,
              iid: bool) -> Stage:
        raise NotImplementedError

    def stages(self, eta1: float, T1: int, k1: float, n_stages: int,
               iid: bool = True) -> List[Stage]:
        return [self.stage(s, eta1, T1, k1, iid)
                for s in range(1, n_stages + 1)]


@dataclass(frozen=True)
class EveryStep(SyncPolicy):
    """k ≡ 1: communicate after every local step (SyncSGD / LB / CR-PSGD)."""

    def stage(self, s, eta1, T1, k1, iid):
        return Stage(s=s, eta=eta1, T=T1, k=1, k_raw=1.0)


@dataclass(frozen=True)
class FixedPeriod(SyncPolicy):
    """k ≡ k₁: Local SGD (Alg. 1) — identical stages, fixed period."""

    def stage(self, s, eta1, T1, k1, iid):
        return Stage(s=s, eta=eta1, T=T1, k=max(1, int(k1)), k_raw=k1)


@dataclass(frozen=True)
class StagewiseGeometric(SyncPolicy):
    """η_{s+1}=η_s/2, T_{s+1}=2T_s, k_{s+1}=2k_s (IID) or √2·k_s (Non-IID).

    Algorithm 2 (STL-SGD^sc) and Algorithm 3 Option 1 (with recenter=True).
    """

    def stage(self, s, eta1, T1, k1, iid):
        kr = k1 * k_growth(iid, True, s)
        return Stage(s=s, eta=eta1 / (2.0 ** (s - 1)), T=T1 * (2 ** (s - 1)),
                     k=max(1, int(kr)), k_raw=kr)


@dataclass(frozen=True)
class StagewiseLinear(SyncPolicy):
    """η_s=η₁/s, T_s=sT₁, k_s=sk₁ (IID) or √s·k₁ (Non-IID).

    Algorithm 3 Option 2 (STL-SGD^nc, linear growth).
    """

    def stage(self, s, eta1, T1, k1, iid):
        kr = k1 * k_growth(iid, False, s)
        return Stage(s=s, eta=eta1 / s, T=T1 * s,
                     k=max(1, int(kr)), k_raw=kr)
