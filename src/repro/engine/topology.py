"""Topology — *where* a communication round's bytes travel.

A topology composes reducers over hops and prices each hop with its own
α–β ``NetworkModel``:

  Star          the paper's setting: every client uplinks to one server
                over a single link (one hop, one reducer).
  Hierarchical  pod/WAN deployment: a dense intra-pod reduce over fast ICI
                followed by a (typically compressed) inter-pod reduce over
                the slow WAN. Clients split into ``n_pods`` equal pods on
                the leading replica axis; pod reductions run in parallel,
                so the intra hop's modeled time uses per-pod bytes while
                its byte count is the total traffic.

Topologies expose the same ``init_state`` / ``reduce`` protocol as a
``comm.Reducer`` (state is a pytree, reduce is jit/scan-safe), so the round
function is agnostic to whether it averages over one hop or two — and
``hop_costs`` replaces the single-link cost model with a per-hop
(latency, bandwidth) list.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.comm.cost import NetworkModel, link_model, round_bytes, round_time
from repro.comm.reducer import DenseMean, Reducer, get_reducer, reduce_streaming


@dataclass(frozen=True)
class HopCost:
    """Modeled cost of one hop of one communication round."""

    hop: str            # "uplink" | "intra_pod" | "inter_pod"
    reducer: str
    network: NetworkModel
    bytes: int          # total traffic crossing the hop per round
    time_s: float       # α + serial_bytes / bandwidth (parallel links once)


@dataclass(frozen=True)
class LeafCost:
    """Modeled cost of ONE leaf's share of one hop of one round.

    The per-leaf comm ledger: ``bytes`` is the total traffic that leaf's
    messages put on the hop per round (all clients), ``time_s`` its share of
    the hop's serial α–β time (the hop latency α is attributed to the
    hop's first leaf once, serialization is bytes/bandwidth). Summing a
    hop's LeafCosts reproduces the tree-level ``HopCost`` — bytes
    bit-exactly (integer per-leaf formulas), seconds to float-sum precision.
    """

    leaf: int           # index into jax.tree.leaves(template)
    path: str           # jax.tree_util.keystr of the leaf
    hop: str            # same hop names as HopCost
    bytes: int          # total per-round traffic of this leaf on this hop
    time_s: float       # this leaf's share of the hop's serial α–β time


def _leaf_paths(template) -> List[str]:
    """Human-readable key paths for every leaf of a template pytree."""
    paths, _ = jax.tree_util.tree_flatten_with_path(template)
    return [jax.tree_util.keystr(p) for p, _ in paths]


class Topology:
    """Base protocol — reducer-compatible reduce + per-hop costing."""

    name = "base"

    def init_state(self, stacked):
        """Reducer state (EF residuals per hop) for the stacked (N, ...)
        replica tree; call at run start when replicas are identical."""
        raise NotImplementedError

    def reduce(self, stacked, state, rng):
        """Route one round: (stacked replicas, state, rng) -> (consensus
        tree without the client axis, new state). jit/scan-safe."""
        raise NotImplementedError

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        """Price one round hop by hop: total payload bytes crossing each
        hop and its serial α–β time in modeled seconds (``template`` is a
        single-replica pytree of arrays or ShapeDtypeStructs)."""
        raise NotImplementedError

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        """Per-(leaf, hop) breakdown of one round's modeled cost.

        Empty by default (a topology without per-leaf accounting); Star,
        StreamingStar and Hierarchical implement it so the engine ledger can
        reconcile streaming per-leaf uploads against tree-level totals.
        """
        return []

    def round_bytes(self, template, n_clients: int) -> int:
        """Total modeled payload bytes one round moves across all hops."""
        return sum(h.bytes for h in self.hop_costs(template, n_clients))

    def round_time(self, template, n_clients: int) -> float:
        """Total serial α–β time of one round across all hops, in modeled
        seconds (parallel intra-pod links are priced once)."""
        return sum(h.time_s for h in self.hop_costs(template, n_clients))

    def summary(self, template, n_clients: int, n_rounds: int) -> dict:
        """Full per-hop comm report for a finished run."""
        hops = self.hop_costs(template, n_clients)
        per_round = sum(h.bytes for h in hops)
        t_round = sum(h.time_s for h in hops)
        return {
            "topology": self.name,
            "rounds": int(n_rounds),
            "bytes_per_round": int(per_round),
            "total_bytes": int(per_round) * int(n_rounds),
            "round_time_s": t_round,
            "total_time_s": t_round * int(n_rounds),
            "hops": [{
                "hop": h.hop, "reducer": h.reducer,
                "latency_s": h.network.latency_s,
                "bandwidth_gbps": h.network.bandwidth_gbps,
                "bytes_per_round": int(h.bytes),
                "time_per_round_s": h.time_s,
                "total_time_s": h.time_s * int(n_rounds),
            } for h in hops],
        }


@dataclass(frozen=True)
class Star(Topology):
    """Flat parameter-server topology — the paper's setting, one hop.

    With ``reducer=DenseMean()`` this is bit-exact with calling the reducer
    directly (the pre-engine behavior).
    """

    reducer: Reducer = field(default_factory=DenseMean)
    network: NetworkModel = field(default_factory=NetworkModel)

    name = "star"

    def init_state(self, stacked):
        return self.reducer.init_state(stacked)

    def reduce(self, stacked, state, rng):
        return self.reducer.reduce(stacked, state, rng)

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        up = round_bytes(self.reducer, template, n_clients, self.network)
        return [HopCost(hop="uplink", reducer=self.reducer.name,
                        network=self.network, bytes=up,
                        time_s=round_time(self.network, up))]

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        try:
            leaf_bytes = self.reducer.leaf_message_bytes(template)
        except NotImplementedError:
            # custom reducers predating the per-leaf protocol (only
            # message_bytes overridden) still run — without a leaf ledger
            return []
        if self.network.count_downlink:
            # mirror round_bytes: the dense broadcast is billed per leaf
            # too, so the ledger still reconciles on count_downlink links
            down = DenseMean().leaf_message_bytes(template)
            leaf_bytes = [b + d for b, d in zip(leaf_bytes, down)]
        paths = _leaf_paths(template)
        out = []
        for i, (b, p) in enumerate(zip(leaf_bytes, paths)):
            total = n_clients * b
            t = total / self.network.bandwidth_Bps
            if i == 0:  # the hop latency α is paid once per round
                t += self.network.latency_s
            out.append(LeafCost(leaf=i, path=p, hop="uplink",
                                bytes=total, time_s=t))
        return out


@dataclass(frozen=True)
class StreamingStar(Star):
    """Star whose reduce runs *per leaf* — the streaming execution topology.

    Numerics are bit-exact with ``Star`` (each leaf is reduced with the
    same per-leaf rng the tree-level reducer folds), but the reduction is
    expressed as one independent ``reduce_leaf`` call per leaf, in
    reverse-layer order — the order leaves finish their last local step
    under backprop. That is the structure a jit'd sync step needs for XLA
    to interleave leaf l's reduce with the remaining leaves' compute, and
    it is what ``local_sgd.build_sync_step(streaming=True)`` emits; the
    cost model (``hop_costs`` / ``leaf_costs``) is inherited unchanged, so
    streaming and blocking ledgers reconcile by construction. The modeled
    *overlap* win is priced by ``runtime.StreamingSchedule``, not here —
    the ledger stays the serial α–β view.
    """

    name = "streaming-star"

    def reduce(self, stacked, state, rng):
        """The per-leaf round: ``comm.reduce_streaming`` over the uplink
        reducer (one shared copy of the reverse-order + per-leaf-rng
        structure, so execution paths cannot drift)."""
        return reduce_streaming(self.reducer, stacked, state, rng)


@dataclass(frozen=True)
class Hierarchical(Topology):
    """Two-level pod topology: intra-pod reduce (fast link), then inter-pod
    reduce over the pod means (slow link).

    The client axis must be divisible by ``n_pods``. Pod p's replicas are
    the contiguous slice [p·m, (p+1)·m) of the leading client axis — the
    layout a ``(pod, data, model)`` mesh shards pod-major, so when the
    stacked replica tree is sharded ``P(("pod", "data"), ...)`` this reduce
    *is* the driver's two-level round: the intra hop (a reshaped mean over
    the per-pod slice) lowers to collectives on the ``data`` mesh axis
    only, and the inter hop (``inter.reduce`` over the ``n_pods`` stacked
    pod means) to collectives on the ``pod`` axis only.
    ``local_sgd.build_sync_step(hierarchical=True)`` executes exactly this
    method, so the driver's collectives and the simulator's hierarchical
    trace are the same code path (bit-exact on the same rng).

    Both levels keep their own reducer state (error-feedback residuals
    live per level), so e.g. a dense ICI average composes with an int8-EF
    WAN round. Per-round rng discipline: pod p's intra reduce folds
    ``fold_in(rng, p)``; the inter reduce folds ``fold_in(rng, n_pods)``.

    Dense∘dense collapse: with ``DenseMean`` on *both* hops the two-level
    round is algebraically the flat mean over all clients (equal-size
    pods), so it is computed as exactly that — one fused mean. This keeps
    the dense-WAN two-level round bit-exact with the flat ``Star`` path
    (the driver's safety-rail contract) instead of merely close to it; the
    per-hop cost model still prices both hops.
    """

    n_pods: int = 2
    intra: Reducer = field(default_factory=DenseMean)
    inter: Reducer = field(default_factory=DenseMean)
    intra_net: NetworkModel = field(default_factory=lambda: link_model("ici"))
    inter_net: NetworkModel = field(default_factory=lambda: link_model("wan"))

    name = "hierarchical"

    @property
    def all_dense(self) -> bool:
        """True when both hops are DenseMean — the collapsible case."""
        return (type(self.intra) is DenseMean
                and type(self.inter) is DenseMean)

    def _pods(self, stacked):
        P = self.n_pods
        return [jax.tree.map(lambda x: x[p * (x.shape[0] // P):
                                         (p + 1) * (x.shape[0] // P)], stacked)
                for p in range(P)]

    def _pod_means(self, stacked):
        """Dense intra hop as one reshaped mean: (N, ...) -> (n_pods, ...).

        The reshape splits the client axis pod-major — a layout no-op on a
        ``P(("pod", "data"))``-sharded axis — so the mean reduces over the
        ``data`` axis only and never crosses pods.
        """
        P = self.n_pods
        return jax.tree.map(
            lambda x: jnp.mean(
                x.reshape((P, x.shape[0] // P) + x.shape[1:]), axis=1),
            stacked)

    def init_state(self, stacked):
        n = jax.tree.leaves(stacked)[0].shape[0]
        if n % self.n_pods:
            raise ValueError(
                f"{n} clients not divisible into {self.n_pods} pods")
        pods = self._pods(stacked)
        return {"intra": tuple(self.intra.init_state(p) for p in pods),
                "inter": self.inter.init_state(self._pod_means(stacked))}

    def reduce(self, stacked, state, rng):
        if self.all_dense:
            # see class docstring: dense∘dense ≡ the flat mean, computed
            # as such so the two-level round is bit-exact with Star
            return jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                stacked), state
        if type(self.intra) is DenseMean:
            # stateless, rng-free intra hop: one fused per-pod mean whose
            # collectives stay on the intra-pod (data) axis under pjit
            stacked_means = self._pod_means(stacked)
            intra_states = state["intra"]
        else:
            means, intra_states = [], []
            for p, pod in enumerate(self._pods(stacked)):
                m, st = self.intra.reduce(pod, state["intra"][p],
                                          jax.random.fold_in(rng, p))
                means.append(m)
                intra_states.append(st)
            stacked_means = jax.tree.map(lambda *xs: jnp.stack(xs), *means)
            intra_states = tuple(intra_states)
        consensus, inter_state = self.inter.reduce(
            stacked_means, state["inter"],
            jax.random.fold_in(rng, self.n_pods))
        return consensus, {"intra": intra_states,
                           "inter": inter_state}

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        if n_clients % self.n_pods:
            # same shape contract as init_state/reduce — pricing must not
            # succeed for a configuration execution would reject
            raise ValueError(
                f"{n_clients} clients not divisible into {self.n_pods} pods")
        m = n_clients // self.n_pods
        intra_msg = self.intra.message_bytes(template)
        inter_msg = self.inter.message_bytes(template)
        intra_total = n_clients * intra_msg
        inter_total = self.n_pods * inter_msg
        return [
            # pods reduce in parallel: time sees one pod's traffic
            HopCost(hop="intra_pod", reducer=self.intra.name,
                    network=self.intra_net, bytes=intra_total,
                    time_s=self.intra_net.latency_s
                    + m * intra_msg / self.intra_net.bandwidth_Bps),
            HopCost(hop="inter_pod", reducer=self.inter.name,
                    network=self.inter_net, bytes=inter_total,
                    time_s=self.inter_net.latency_s
                    + inter_total / self.inter_net.bandwidth_Bps),
        ]

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        """Per-leaf ledger across both hops, mirroring ``hop_costs``:
        intra-pod time sees one pod's per-leaf traffic (pods run in
        parallel), inter-pod time the pod-mean messages; each hop's α is
        attributed to its first leaf once."""
        if n_clients % self.n_pods:
            raise ValueError(
                f"{n_clients} clients not divisible into {self.n_pods} pods")
        m = n_clients // self.n_pods
        paths = _leaf_paths(template)
        out = []
        try:
            per_hop = [self.intra.leaf_message_bytes(template),
                       self.inter.leaf_message_bytes(template)]
        except NotImplementedError:
            return []  # pre-per-leaf-protocol custom reducer: no ledger
        for (hop, red, net, mult, tmult), hop_bytes in zip((
                ("intra_pod", self.intra, self.intra_net, n_clients, m),
                ("inter_pod", self.inter, self.inter_net, self.n_pods,
                 self.n_pods)), per_hop):
            for i, (b, p) in enumerate(zip(hop_bytes, paths)):
                t = tmult * b / net.bandwidth_Bps
                if i == 0:
                    t += net.latency_s
                out.append(LeafCost(leaf=i, path=p, hop=hop,
                                    bytes=mult * b, time_s=t))
        return out


def get_topology(spec, *, reducer=None, network: Optional[NetworkModel] = None,
                 n_pods: int = 2, inter_reducer=None,
                 quant_bits: int = 8, topk_frac: float = 0.1) -> Topology:
    """Resolve a topology from a config string (or pass one through).

    "star" (default) wraps ``reducer`` in the single-hop paper topology;
    "streaming"/"streaming-star" is the same hop but reduced per leaf
    (communication/compute overlap — see ``StreamingStar``);
    "hier"/"hierarchical" composes ``reducer`` intra-pod (dense by default)
    with ``inter_reducer`` (int8 by default) inter-pod over calibrated
    ICI/WAN links.
    """
    if isinstance(spec, Topology):
        return spec
    red = get_reducer(reducer, quant_bits=quant_bits, topk_frac=topk_frac)
    if spec in (None, "star", "flat"):
        return Star(reducer=red, network=network or NetworkModel())
    if spec in ("streaming", "streaming-star", "stream"):
        return StreamingStar(reducer=red, network=network or NetworkModel())
    if spec in ("hier", "hierarchical", "pods"):
        inter = get_reducer(inter_reducer if inter_reducer is not None
                            else "int8", quant_bits=quant_bits,
                            topk_frac=topk_frac)
        return Hierarchical(n_pods=n_pods, intra=red, inter=inter,
                            intra_net=link_model("ici"),
                            inter_net=network or link_model("wan"))
    raise ValueError(f"unknown topology spec: {spec!r}")
