"""Topology — *where* a communication round's bytes travel.

A topology composes reducers over hops and prices each hop with its own
α–β ``NetworkModel``:

  Star          the paper's setting: every client uplinks to one server
                over a single link (one hop, one reducer).
  Hierarchical  pod/WAN deployment: a dense intra-pod reduce over fast ICI
                followed by a (typically compressed) inter-pod reduce over
                the slow WAN. Clients split into ``n_pods`` equal pods on
                the leading replica axis; pod reductions run in parallel,
                so the intra hop's modeled time uses per-pod bytes while
                its byte count is the total traffic.

Topologies expose the same ``init_state`` / ``reduce`` protocol as a
``comm.Reducer`` (state is a pytree, reduce is jit/scan-safe), so the round
function is agnostic to whether it averages over one hop or two — and
``hop_costs`` replaces the single-link cost model with a per-hop
(latency, bandwidth) list.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.comm.cost import NetworkModel, link_model, round_bytes, round_time
from repro.comm.reducer import DenseMean, Reducer, get_reducer


@dataclass(frozen=True)
class HopCost:
    """Modeled cost of one hop of one communication round."""

    hop: str            # "uplink" | "intra_pod" | "inter_pod"
    reducer: str
    network: NetworkModel
    bytes: int          # total traffic crossing the hop per round
    time_s: float       # α + serial_bytes / bandwidth (parallel links once)


class Topology:
    """Base protocol — reducer-compatible reduce + per-hop costing."""

    name = "base"

    def init_state(self, stacked):
        raise NotImplementedError

    def reduce(self, stacked, state, rng):
        raise NotImplementedError

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        raise NotImplementedError

    def round_bytes(self, template, n_clients: int) -> int:
        return sum(h.bytes for h in self.hop_costs(template, n_clients))

    def round_time(self, template, n_clients: int) -> float:
        return sum(h.time_s for h in self.hop_costs(template, n_clients))

    def summary(self, template, n_clients: int, n_rounds: int) -> dict:
        """Full per-hop comm report for a finished run."""
        hops = self.hop_costs(template, n_clients)
        per_round = sum(h.bytes for h in hops)
        t_round = sum(h.time_s for h in hops)
        return {
            "topology": self.name,
            "rounds": int(n_rounds),
            "bytes_per_round": int(per_round),
            "total_bytes": int(per_round) * int(n_rounds),
            "round_time_s": t_round,
            "total_time_s": t_round * int(n_rounds),
            "hops": [{
                "hop": h.hop, "reducer": h.reducer,
                "latency_s": h.network.latency_s,
                "bandwidth_gbps": h.network.bandwidth_gbps,
                "bytes_per_round": int(h.bytes),
                "time_per_round_s": h.time_s,
                "total_time_s": h.time_s * int(n_rounds),
            } for h in hops],
        }


@dataclass(frozen=True)
class Star(Topology):
    """Flat parameter-server topology — the paper's setting, one hop.

    With ``reducer=DenseMean()`` this is bit-exact with calling the reducer
    directly (the pre-engine behavior).
    """

    reducer: Reducer = field(default_factory=DenseMean)
    network: NetworkModel = field(default_factory=NetworkModel)

    name = "star"

    def init_state(self, stacked):
        return self.reducer.init_state(stacked)

    def reduce(self, stacked, state, rng):
        return self.reducer.reduce(stacked, state, rng)

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        up = round_bytes(self.reducer, template, n_clients, self.network)
        return [HopCost(hop="uplink", reducer=self.reducer.name,
                        network=self.network, bytes=up,
                        time_s=round_time(self.network, up))]


@dataclass(frozen=True)
class Hierarchical(Topology):
    """Two-level pod topology: intra-pod reduce (fast link), then inter-pod
    reduce over the pod means (slow link).

    The client axis must be divisible by ``n_pods``. Pod p's replicas are
    the contiguous slice [p·m, (p+1)·m). Both levels keep their own reducer
    state (error-feedback residuals live per level), so e.g. a dense ICI
    average composes with an int8-EF WAN round.
    """

    n_pods: int = 2
    intra: Reducer = field(default_factory=DenseMean)
    inter: Reducer = field(default_factory=DenseMean)
    intra_net: NetworkModel = field(default_factory=lambda: link_model("ici"))
    inter_net: NetworkModel = field(default_factory=lambda: link_model("wan"))

    name = "hierarchical"

    def _pods(self, stacked):
        P = self.n_pods
        return [jax.tree.map(lambda x: x[p * (x.shape[0] // P):
                                         (p + 1) * (x.shape[0] // P)], stacked)
                for p in range(P)]

    def init_state(self, stacked):
        n = jax.tree.leaves(stacked)[0].shape[0]
        if n % self.n_pods:
            raise ValueError(
                f"{n} clients not divisible into {self.n_pods} pods")
        pods = self._pods(stacked)
        pod_means = [jax.tree.map(lambda x: jnp.mean(x, axis=0), p)
                     for p in pods]
        stacked_means = jax.tree.map(lambda *xs: jnp.stack(xs), *pod_means)
        return {"intra": tuple(self.intra.init_state(p) for p in pods),
                "inter": self.inter.init_state(stacked_means)}

    def reduce(self, stacked, state, rng):
        pods = self._pods(stacked)
        means, intra_states = [], []
        for p, pod in enumerate(pods):
            m, st = self.intra.reduce(pod, state["intra"][p],
                                      jax.random.fold_in(rng, p))
            means.append(m)
            intra_states.append(st)
        stacked_means = jax.tree.map(lambda *xs: jnp.stack(xs), *means)
        consensus, inter_state = self.inter.reduce(
            stacked_means, state["inter"],
            jax.random.fold_in(rng, self.n_pods))
        return consensus, {"intra": tuple(intra_states),
                           "inter": inter_state}

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        if n_clients % self.n_pods:
            # same shape contract as init_state/reduce — pricing must not
            # succeed for a configuration execution would reject
            raise ValueError(
                f"{n_clients} clients not divisible into {self.n_pods} pods")
        m = n_clients // self.n_pods
        intra_msg = self.intra.message_bytes(template)
        inter_msg = self.inter.message_bytes(template)
        intra_total = n_clients * intra_msg
        inter_total = self.n_pods * inter_msg
        return [
            # pods reduce in parallel: time sees one pod's traffic
            HopCost(hop="intra_pod", reducer=self.intra.name,
                    network=self.intra_net, bytes=intra_total,
                    time_s=self.intra_net.latency_s
                    + m * intra_msg / self.intra_net.bandwidth_Bps),
            HopCost(hop="inter_pod", reducer=self.inter.name,
                    network=self.inter_net, bytes=inter_total,
                    time_s=self.inter_net.latency_s
                    + inter_total / self.inter_net.bandwidth_Bps),
        ]


def get_topology(spec, *, reducer=None, network: Optional[NetworkModel] = None,
                 n_pods: int = 2, inter_reducer=None,
                 quant_bits: int = 8, topk_frac: float = 0.1) -> Topology:
    """Resolve a topology from a config string (or pass one through).

    "star" (default) wraps ``reducer`` in the single-hop paper topology;
    "hier"/"hierarchical" composes ``reducer`` intra-pod (dense by default)
    with ``inter_reducer`` (int8 by default) inter-pod over calibrated
    ICI/WAN links.
    """
    if isinstance(spec, Topology):
        return spec
    red = get_reducer(reducer, quant_bits=quant_bits, topk_frac=topk_frac)
    if spec in (None, "star", "flat"):
        return Star(reducer=red, network=network or NetworkModel())
    if spec in ("hier", "hierarchical", "pods"):
        inter = get_reducer(inter_reducer if inter_reducer is not None
                            else "int8", quant_bits=quant_bits,
                            topk_frac=topk_frac)
        return Hierarchical(n_pods=n_pods, intra=red, inter=inter,
                            intra_net=link_model("ici"),
                            inter_net=network or link_model("wan"))
    raise ValueError(f"unknown topology spec: {spec!r}")
