"""Topology — *where* a communication round's bytes travel.

A topology composes reducers over hops and prices each hop with its own
α–β ``NetworkModel``:

  Star          the paper's setting: every client uplinks to one server
                over a single link (one hop, one reducer).
  Hierarchical  pod/WAN deployment: a dense intra-pod reduce over fast ICI
                followed by a (typically compressed) inter-pod reduce over
                the slow WAN. Clients split into ``n_pods`` equal pods on
                the leading replica axis; pod reductions run in parallel,
                so the intra hop's modeled time uses per-pod bytes while
                its byte count is the total traffic.

Topologies expose the same ``init_state`` / ``reduce`` protocol as a
``comm.Reducer`` (state is a pytree, reduce is jit/scan-safe), so the round
function is agnostic to whether it averages over one hop or two — and
``hop_costs`` replaces the single-link cost model with a per-hop
(latency, bandwidth) list.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.comm.cost import (NetworkModel, dense_bytes, link_model,
                             round_time)
from repro.comm.reducer import (DenseMean, Reducer, get_reducer,
                                reduce_streaming, supports_leaf_bytes)


@dataclass(frozen=True)
class HopCost:
    """Modeled cost of one hop of one communication round."""

    hop: str            # "uplink" | "intra_pod" | "inter_pod" | "downlink"
    reducer: str
    network: NetworkModel
    bytes: int          # total traffic crossing the hop per round
    time_s: float       # α + serial_bytes / bandwidth (parallel links once)


@dataclass(frozen=True)
class LeafCost:
    """Modeled cost of ONE leaf's share of one hop of one round.

    The per-leaf comm ledger: ``bytes`` is the total traffic that leaf's
    messages put on the hop per round (all clients), ``time_s`` its share of
    the hop's serial α–β time (the hop latency α is attributed to the
    hop's first leaf once, serialization is bytes/bandwidth). Summing a
    hop's LeafCosts reproduces the tree-level ``HopCost`` — bytes
    bit-exactly (integer per-leaf formulas), seconds to float-sum precision.
    """

    leaf: int           # index into jax.tree.leaves(template)
    path: str           # jax.tree_util.keystr of the leaf
    hop: str            # same hop names as HopCost
    bytes: int          # total per-round traffic of this leaf on this hop
    time_s: float       # this leaf's share of the hop's serial α–β time


def _leaf_paths(template) -> List[str]:
    """Human-readable key paths for every leaf of a template pytree."""
    paths, _ = jax.tree_util.tree_flatten_with_path(template)
    return [jax.tree_util.keystr(p) for p, _ in paths]


def _hop_leaf_costs(hop: str, leaf_bytes, paths, net: NetworkModel, *,
                    mult: int, tmult: Optional[int] = None) -> List[LeafCost]:
    """One hop's LeafCost rows from per-leaf message bytes.

    ``bytes`` is ``mult`` messages' worth of traffic per leaf; time is
    ``tmult`` (default ``mult``) messages' serialization on ``net`` — they
    differ only for parallel intra-pod links, where the hop's byte count is
    the total traffic but its time sees one pod's. The hop latency α is
    attributed to the hop's first leaf once.
    """
    tmult = mult if tmult is None else tmult
    out = []
    for i, (b, p) in enumerate(zip(leaf_bytes, paths)):
        t = tmult * b / net.bandwidth_Bps
        if i == 0:
            t += net.latency_s
        out.append(LeafCost(leaf=i, path=p, hop=hop,
                            bytes=mult * b, time_s=t))
    return out


class Topology:
    """Base protocol — reducer-compatible reduce + per-hop costing."""

    name = "base"

    def init_state(self, stacked):
        """Reducer state (EF residuals per hop) for the stacked (N, ...)
        replica tree; call at run start when replicas are identical."""
        raise NotImplementedError

    def reduce(self, stacked, state, rng):
        """Route one round: (stacked replicas, state, rng) -> (consensus
        tree without the client axis, new state). jit/scan-safe."""
        raise NotImplementedError

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        """Price one round hop by hop: total payload bytes crossing each
        hop and its serial α–β time in modeled seconds (``template`` is a
        single-replica pytree of arrays or ShapeDtypeStructs)."""
        raise NotImplementedError

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        """Per-(leaf, hop) breakdown of one round's modeled cost.

        Empty by default (a topology without per-leaf accounting); Star,
        StreamingStar and Hierarchical implement it so the engine ledger can
        reconcile streaming per-leaf uploads against tree-level totals.
        """
        return []

    def round_bytes(self, template, n_clients: int) -> int:
        """Total modeled payload bytes one round moves across all hops."""
        return sum(h.bytes for h in self.hop_costs(template, n_clients))

    def round_time(self, template, n_clients: int) -> float:
        """Total serial α–β time of one round across all hops, in modeled
        seconds (parallel intra-pod links are priced once)."""
        return sum(h.time_s for h in self.hop_costs(template, n_clients))

    def summary(self, template, n_clients: int, n_rounds: int) -> dict:
        """Full per-hop comm report for a finished run."""
        hops = self.hop_costs(template, n_clients)
        per_round = sum(h.bytes for h in hops)
        t_round = sum(h.time_s for h in hops)
        return {
            "topology": self.name,
            "rounds": int(n_rounds),
            "bytes_per_round": int(per_round),
            "total_bytes": int(per_round) * int(n_rounds),
            "round_time_s": t_round,
            "total_time_s": t_round * int(n_rounds),
            "hops": [{
                "hop": h.hop, "reducer": h.reducer,
                "latency_s": h.network.latency_s,
                "bandwidth_gbps": h.network.bandwidth_gbps,
                "bytes_per_round": int(h.bytes),
                "time_per_round_s": h.time_s,
                "total_time_s": h.time_s * int(n_rounds),
            } for h in hops],
        }


@dataclass(frozen=True)
class Star(Topology):
    """Flat parameter-server topology — the paper's setting, one hop.

    With ``reducer=DenseMean()`` this is bit-exact with calling the reducer
    directly (the pre-engine behavior).
    """

    reducer: Reducer = field(default_factory=DenseMean)
    network: NetworkModel = field(default_factory=NetworkModel)

    name = "star"

    def init_state(self, stacked):
        return self.reducer.init_state(stacked)

    def reduce(self, stacked, state, rng):
        return self.reducer.reduce(stacked, state, rng)

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        up = n_clients * self.reducer.message_bytes(template)
        hops = [HopCost(hop="uplink", reducer=self.reducer.name,
                        network=self.network, bytes=up,
                        time_s=round_time(self.network, up))]
        if self.network.count_downlink:
            # the dense server broadcast is its own hop (cost_model.md:
            # reducer-independent, billed only on count_downlink links) —
            # sum of hop bytes still equals ``cost.round_bytes``
            down = n_clients * dense_bytes(template)
            hops.append(HopCost(hop="downlink", reducer="dense",
                                network=self.network, bytes=down,
                                time_s=round_time(self.network, down)))
        return hops

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        if not supports_leaf_bytes(self.reducer):
            # custom reducers predating the per-leaf protocol (only
            # message_bytes overridden) still run — without a leaf ledger
            return []
        leaf_bytes = self.reducer.leaf_message_bytes(template)
        paths = _leaf_paths(template)
        out = _hop_leaf_costs("uplink", leaf_bytes, paths, self.network,
                              mult=n_clients)
        if self.network.count_downlink:
            # mirror hop_costs: the dense broadcast gets its own downlink
            # rows, so the ledger reconciles hop by hop
            down = DenseMean().leaf_message_bytes(template)
            out += _hop_leaf_costs("downlink", down, paths, self.network,
                                   mult=n_clients)
        return out


@dataclass(frozen=True)
class StreamingStar(Star):
    """Star whose reduce runs *per leaf* — the streaming execution topology.

    Numerics are bit-exact with ``Star`` (each leaf is reduced with the
    same per-leaf rng the tree-level reducer folds), but the reduction is
    expressed as one independent ``reduce_leaf`` call per leaf, in
    reverse-layer order — the order leaves finish their last local step
    under backprop. That is the structure a jit'd sync step needs for XLA
    to interleave leaf l's reduce with the remaining leaves' compute, and
    it is what ``local_sgd.build_sync_step(streaming=True)`` emits; the
    cost model (``hop_costs`` / ``leaf_costs``) is inherited unchanged, so
    streaming and blocking ledgers reconcile by construction. The modeled
    *overlap* win is priced by ``runtime.StreamingSchedule``, not here —
    the ledger stays the serial α–β view.
    """

    name = "streaming-star"

    def reduce(self, stacked, state, rng):
        """The per-leaf round: ``comm.reduce_streaming`` over the uplink
        reducer (one shared copy of the reverse-order + per-leaf-rng
        structure, so execution paths cannot drift)."""
        return reduce_streaming(self.reducer, stacked, state, rng)


@dataclass(frozen=True)
class Hierarchical(Topology):
    """Two-level pod topology: intra-pod reduce (fast link), then inter-pod
    reduce over the pod means (slow link).

    The client axis must be divisible by ``n_pods``. Pod p's replicas are
    the contiguous slice [p·m, (p+1)·m) of the leading client axis — the
    layout a ``(pod, data, model)`` mesh shards pod-major, so when the
    stacked replica tree is sharded ``P(("pod", "data"), ...)`` this reduce
    *is* the driver's two-level round: the intra hop (a reshaped mean over
    the per-pod slice) lowers to collectives on the ``data`` mesh axis
    only, and the inter hop (``inter.reduce`` over the ``n_pods`` stacked
    pod means) to collectives on the ``pod`` axis only.
    ``local_sgd.build_sync_step(hierarchical=True)`` executes exactly this
    method, so the driver's collectives and the simulator's hierarchical
    trace are the same code path (bit-exact on the same rng).

    Both levels keep their own reducer state (error-feedback residuals
    live per level), so e.g. a dense ICI average composes with an int8-EF
    WAN round. Per-round rng discipline: pod p's intra reduce folds
    ``fold_in(rng, p)``; the inter reduce folds ``fold_in(rng, n_pods)``.

    Dense∘dense collapse: with ``DenseMean`` on *both* hops the two-level
    round is algebraically the flat mean over all clients (equal-size
    pods), so it is computed as exactly that — one fused mean. This keeps
    the dense-WAN two-level round bit-exact with the flat ``Star`` path
    (the driver's safety-rail contract) instead of merely close to it; the
    per-hop cost model still prices both hops.

    ``streaming=True`` is the streaming∘hierarchical composition: the
    two-level round runs *per leaf* in reverse-layer order — leaf l's
    intra-pod reduce feeds its inter-pod reduce immediately, so the WAN
    hop of early-finishing leaves overlaps the intra-pod reduction of the
    remaining leaves. Numerics are bit-exact with the blocking
    ``Hierarchical`` round (every hop folds the same per-leaf rng its
    tree-level reduce folds), the cost model is inherited unchanged (the
    ledger stays the serial α–β view), and the modeled overlap win is
    priced by ``runtime.StreamingSchedule``. At ``n_pods=1`` the spec
    resolver (``get_topology``) degenerates the round to ``StreamingStar``
    (flat ``Star`` when blocking) — the single-pod round *is* the flat
    round, matching the driver contract.
    """

    n_pods: int = 2
    intra: Reducer = field(default_factory=DenseMean)
    inter: Reducer = field(default_factory=DenseMean)
    intra_net: NetworkModel = field(default_factory=lambda: link_model("ici"))
    inter_net: NetworkModel = field(default_factory=lambda: link_model("wan"))
    streaming: bool = False

    @property
    def name(self) -> str:
        return "streaming-hier" if self.streaming else "hierarchical"

    @property
    def all_dense(self) -> bool:
        """True when both hops are DenseMean — the collapsible case."""
        return (type(self.intra) is DenseMean
                and type(self.inter) is DenseMean)

    def _pods(self, stacked):
        P = self.n_pods
        return [jax.tree.map(lambda x: x[p * (x.shape[0] // P):
                                         (p + 1) * (x.shape[0] // P)], stacked)
                for p in range(P)]

    def _pod_means(self, stacked):
        """Dense intra hop as one reshaped mean: (N, ...) -> (n_pods, ...).

        The reshape splits the client axis pod-major — a layout no-op on a
        ``P(("pod", "data"))``-sharded axis — so the mean reduces over the
        ``data`` axis only and never crosses pods.
        """
        P = self.n_pods
        return jax.tree.map(
            lambda x: jnp.mean(
                x.reshape((P, x.shape[0] // P) + x.shape[1:]), axis=1),
            stacked)

    def init_state(self, stacked):
        n = jax.tree.leaves(stacked)[0].shape[0]
        if n % self.n_pods:
            raise ValueError(
                f"{n} clients not divisible into {self.n_pods} pods")
        pods = self._pods(stacked)
        return {"intra": tuple(self.intra.init_state(p) for p in pods),
                "inter": self.inter.init_state(self._pod_means(stacked))}

    def reduce(self, stacked, state, rng):
        if self.streaming:
            return self._reduce_streaming(stacked, state, rng)
        if self.all_dense:
            # see class docstring: dense∘dense ≡ the flat mean, computed
            # as such so the two-level round is bit-exact with Star
            return jax.tree.map(lambda x: jnp.mean(x, axis=0),
                                stacked), state
        if type(self.intra) is DenseMean:
            # stateless, rng-free intra hop: one fused per-pod mean whose
            # collectives stay on the intra-pod (data) axis under pjit
            stacked_means = self._pod_means(stacked)
            intra_states = state["intra"]
        else:
            means, intra_states = [], []
            for p, pod in enumerate(self._pods(stacked)):
                m, st = self.intra.reduce(pod, state["intra"][p],
                                          jax.random.fold_in(rng, p))
                means.append(m)
                intra_states.append(st)
            stacked_means = jax.tree.map(lambda *xs: jnp.stack(xs), *means)
            intra_states = tuple(intra_states)
        consensus, inter_state = self.inter.reduce(
            stacked_means, state["inter"],
            jax.random.fold_in(rng, self.n_pods))
        return consensus, {"intra": intra_states,
                           "inter": inter_state}

    def _reduce_streaming(self, stacked, state, rng):
        """The per-leaf two-level round (``streaming=True`` execution).

        Leaves run in reverse-layer order; for each leaf the intra-pod
        reduce feeds the inter-pod reduce immediately. Bit-exactness with
        the blocking ``reduce``: pod p's intra hop folds
        ``fold_in(fold_in(rng, p), leaf)`` — exactly what
        ``intra.reduce``'s internal per-leaf loop folds under
        ``fold_in(rng, p)`` — and the inter hop folds
        ``fold_in(fold_in(rng, n_pods), leaf)`` likewise. The dense-intra
        fused-pod-means and dense∘dense flat-mean specializations of the
        blocking path are preserved per leaf (state passes through
        untouched where the blocking round leaves it untouched).
        """
        leaves, treedef = jax.tree.flatten(stacked)
        P = self.n_pods
        if self.all_dense:
            out = [None] * len(leaves)
            for i in reversed(range(len(leaves))):
                out[i] = jnp.mean(leaves[i], axis=0)
            return treedef.unflatten(out), state
        dense_intra = type(self.intra) is DenseMean
        if not dense_intra:
            intra_states = [self.intra.split_state(state["intra"][p], treedef)
                            for p in range(P)]
        inter_states = self.inter.split_state(state["inter"], treedef)
        out = [None] * len(leaves)
        for i in reversed(range(len(leaves))):
            x = leaves[i]
            m = x.shape[0] // P
            if dense_intra:
                pod_means = jnp.mean(
                    x.reshape((P, m) + x.shape[1:]), axis=1)
            else:
                pms = []
                for p in range(P):
                    pm, intra_states[p][i] = self.intra.reduce_leaf(
                        x[p * m:(p + 1) * m], intra_states[p][i],
                        jax.random.fold_in(jax.random.fold_in(rng, p), i))
                    pms.append(pm)
                pod_means = jnp.stack(pms)
            out[i], inter_states[i] = self.inter.reduce_leaf(
                pod_means, inter_states[i],
                jax.random.fold_in(jax.random.fold_in(rng, P), i))
        new_intra = (state["intra"] if dense_intra else
                     tuple(self.intra.join_state(intra_states[p], treedef)
                           for p in range(P)))
        return treedef.unflatten(out), {
            "intra": new_intra,
            "inter": self.inter.join_state(inter_states, treedef)}

    def hop_costs(self, template, n_clients: int) -> List[HopCost]:
        if n_clients % self.n_pods:
            # same shape contract as init_state/reduce — pricing must not
            # succeed for a configuration execution would reject
            raise ValueError(
                f"{n_clients} clients not divisible into {self.n_pods} pods")
        m = n_clients // self.n_pods
        intra_msg = self.intra.message_bytes(template)
        inter_msg = self.inter.message_bytes(template)
        intra_total = n_clients * intra_msg
        inter_total = self.n_pods * inter_msg
        hops = [
            # pods reduce in parallel: time sees one pod's traffic
            HopCost(hop="intra_pod", reducer=self.intra.name,
                    network=self.intra_net, bytes=intra_total,
                    time_s=self.intra_net.latency_s
                    + m * intra_msg / self.intra_net.bandwidth_Bps),
            HopCost(hop="inter_pod", reducer=self.inter.name,
                    network=self.inter_net, bytes=inter_total,
                    time_s=self.inter_net.latency_s
                    + inter_total / self.inter_net.bandwidth_Bps),
        ]
        if self.inter_net.count_downlink:
            # the global consensus broadcast rides the slow (WAN) link back
            # to every client — dense and reducer-independent, like Star's
            down = n_clients * dense_bytes(template)
            hops.append(HopCost(hop="downlink", reducer="dense",
                                network=self.inter_net, bytes=down,
                                time_s=round_time(self.inter_net, down)))
        return hops

    def leaf_costs(self, template, n_clients: int) -> List[LeafCost]:
        """Per-leaf ledger across both hops, mirroring ``hop_costs``:
        intra-pod time sees one pod's per-leaf traffic (pods run in
        parallel), inter-pod time the pod-mean messages; each hop's α is
        attributed to its first leaf once."""
        if n_clients % self.n_pods:
            raise ValueError(
                f"{n_clients} clients not divisible into {self.n_pods} pods")
        if not (supports_leaf_bytes(self.intra)
                and supports_leaf_bytes(self.inter)):
            return []  # pre-per-leaf-protocol custom reducer: no ledger
        m = n_clients // self.n_pods
        paths = _leaf_paths(template)
        out = _hop_leaf_costs("intra_pod",
                              self.intra.leaf_message_bytes(template),
                              paths, self.intra_net,
                              mult=n_clients, tmult=m)
        out += _hop_leaf_costs("inter_pod",
                               self.inter.leaf_message_bytes(template),
                               paths, self.inter_net, mult=self.n_pods)
        if self.inter_net.count_downlink:
            out += _hop_leaf_costs("downlink",
                                   DenseMean().leaf_message_bytes(template),
                                   paths, self.inter_net, mult=n_clients)
        return out


def get_topology(spec, *, reducer=None, network: Optional[NetworkModel] = None,
                 n_pods: int = 2, inter_reducer=None,
                 quant_bits: int = 8, topk_frac: float = 0.1) -> Topology:
    """Resolve a topology from a config string (or pass one through).

    "star" (default) wraps ``reducer`` in the single-hop paper topology;
    "streaming"/"streaming-star" is the same hop but reduced per leaf
    (communication/compute overlap — see ``StreamingStar``);
    "hier"/"hierarchical" composes ``reducer`` intra-pod (dense by default)
    with ``inter_reducer`` (int8 by default) inter-pod over calibrated
    ICI/WAN links; "streaming-hier"/"hier-streaming" is the same two-level
    round reduced per leaf (``Hierarchical(streaming=True)``).

    Single-pod degeneracy: a hierarchical spec with ``n_pods=1`` has no
    inter-pod link, so it resolves to the flat round over ``reducer`` —
    ``Star`` (blocking) or ``StreamingStar`` (streaming) — matching the
    driver/``build_sync_step`` contract that one pod *is* the flat star.
    """
    if isinstance(spec, Topology):
        return spec
    red = get_reducer(reducer, quant_bits=quant_bits, topk_frac=topk_frac)
    if spec in (None, "star", "flat"):
        return Star(reducer=red, network=network or NetworkModel())
    if spec in ("streaming", "streaming-star", "stream"):
        return StreamingStar(reducer=red, network=network or NetworkModel())
    hier_specs = ("hier", "hierarchical", "pods")
    stream_hier_specs = ("streaming-hier", "hier-streaming",
                         "streaming-hierarchical")
    if spec in hier_specs + stream_hier_specs:
        streaming = spec in stream_hier_specs
        if n_pods == 1:
            cls = StreamingStar if streaming else Star
            return cls(reducer=red, network=network or NetworkModel())
        inter = get_reducer(inter_reducer if inter_reducer is not None
                            else "int8", quant_bits=quant_bits,
                            topk_frac=topk_frac)
        return Hierarchical(n_pods=n_pods, intra=red, inter=inter,
                            intra_net=link_model("ici"),
                            inter_net=network or link_model("wan"),
                            streaming=streaming)
    raise ValueError(f"unknown topology spec: {spec!r}")
