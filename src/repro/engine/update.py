"""LocalUpdate — *how* each client steps between communication rounds.

The per-client update rule, factored out of the old ``simulate.run`` string
branches. A LocalUpdate owns the round's minibatch policy (size, growth,
per-example weighting) while the optimizer arithmetic stays in the round
function — so LB-SGD and CR-PSGD become *update rules*, not special cases
of the driver loop.

  SgdUpdate          fixed batch B (the paper's default)
  LargeBatchUpdate   B ×= factor, meant to pair with EveryStep (LB-SGD)
  GrowingBatchUpdate CR-PSGD [38]: batch grows geometrically per iteration,
                     realised as a masked fixed-size buffer with per-example
                     weights so the compiled step stays shape-stable.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LocalUpdate:
    """Base rule: fixed batch, unweighted loss."""

    name = "sgd"

    def round_batch(self, cfg) -> int:
        """Per-client minibatch buffer size for this run."""
        return cfg.batch_per_client

    def growth(self, cfg) -> float:
        """Per-iteration batch growth factor (1.0 = fixed batch)."""
        return 1.0

    def make_loss(self, ploss):
        """Wrap a (params, batch, center) loss into the 4-arg
        (params, batch, center, weights) form the round function calls.
        The base rule ignores the weights (uniform minibatch mean)."""
        return lambda params, batch, center, weights: ploss(
            params, batch, center)


@dataclass(frozen=True)
class SgdUpdate(LocalUpdate):
    pass


@dataclass(frozen=True)
class LargeBatchUpdate(LocalUpdate):
    """LB-SGD: k=1 with an inflated per-step batch."""

    factor: int = 4
    name = "large_batch"

    def round_batch(self, cfg) -> int:
        return cfg.batch_per_client * self.factor


@dataclass(frozen=True)
class GrowingBatchUpdate(LocalUpdate):
    """CR-PSGD: batch bt = min(max_batch, b0·ρ^t), masked into a fixed
    buffer. The loss is a per-example weighted sum so masked slots
    contribute exactly zero — bit-exact with the old crpsgd branch."""

    name = "growing_batch"

    def round_batch(self, cfg) -> int:
        return cfg.max_batch

    def growth(self, cfg) -> float:
        return cfg.batch_growth

    def make_loss(self, ploss):
        def wloss(params, batch, center, weights):
            per = jax.vmap(
                lambda x: ploss(params, jax.tree.map(lambda a: a[None], x),
                                center)
            )(batch)
            return jnp.sum(per * weights)

        return wloss
