# Pallas TPU kernels for the compute hot-spots of the models the paper's
# algorithm trains/serves (the paper's own contribution is a communication
# schedule — kernel-free — so kernels/ serves the substrate):
#   flash_attention/  blockwise online-softmax attention (causal/window/softcap/GQA)
#   fused_update/     fused momentum-SGD update (Local SGD's k-per-round inner loop)
#   quantize/         fused stochastic-round quantize + dequant-accumulate
#                     (the compressed communication round, repro.comm)
#   ssd/              Mamba2 SSD chunked scan in matmul-dual (MXU) form
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (public
# jit-able wrapper), ref.py (pure-jnp oracle used by the allclose tests).
