"""Blockwise (flash) attention TPU kernel — pl.pallas_call + BlockSpec.

Online-softmax attention tiled for VMEM: the grid is (batch, q-head, q-block,
kv-block); the kv-block axis is innermost, so the running max / normalizer /
accumulator live in VMEM scratch across kv steps (TPU grids execute
sequentially over the last axis). Causal, sliding-window and logit-softcap
masks are fused; GQA is handled by indexing the kv head as h // group.

Block shapes are MXU-aligned (multiples of 128 on the q/kv tile dims; head
dim padded to 128 by the wrapper when needed). Validated on CPU with
interpret=True against ref.attention_ref; the TPU path is the deploy target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], bq: int, bk: int, nk: int,
                 seq_off: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # positions: queries sit at the tail of the kv sequence (self-attention
    # when seq_off == 0 and Sq == Sk).
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + seq_off
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new == NEG_INF): exp underflows to 0 anyway
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) → (B, Sq, H, D) in q.dtype."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    seq_off = Sk - Sq

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, seq_off=seq_off)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),   # running max m
            _vmem((bq, 1), jnp.float32),   # running normalizer l
            _vmem((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
