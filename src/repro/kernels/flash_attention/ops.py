"""Jit'd public wrapper for the flash-attention kernel.

``flash_attention(..., impl=)``:
  * "pallas"     — TPU kernel (deploy target)
  * "interpret"  — same kernel body executed in Python on CPU (validation)
  * "xla"        — the pure-jnp oracle (ref.py)

A recompute-based custom VJP makes the kernel trainable without a handwritten
backward: the forward uses the kernel, the backward differentiates the oracle
(identical math, checked by tests to ~1e-6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, softcap, scale, interpret):
    return K.flash_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, softcap, scale, interpret):
    out = _flash(q, k, v, causal, window, softcap, scale, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, scale, interpret, res, g):
    q, k, v = res

    def oracle(q, k, v):
        return R.attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale).astype(q.dtype)

    _, vjp = jax.vjp(oracle, q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    impl: str = "interpret"):
    if impl == "xla":
        return R.attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale).astype(q.dtype)
    return _flash(q, k, v, causal, window, softcap, scale, impl == "interpret")
