"""Pure-jnp oracle for the flash-attention kernel.

Semantics: causal grouped-query attention with optional sliding window and
logit soft-capping — exactly the masks the model stack uses
(repro.models.attention), restated independently so kernel bugs can't hide
behind shared code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D). Returns (B, Sq, H, D) fp32.

    Queries are assumed to occupy the last Sq positions of the Sk-long
    context (standard self-attention when Sq == Sk).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)

    pos_q = jnp.arange(Sq) + (Sk - Sq)
    pos_k = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        ok &= pos_k[None, :] > pos_q[:, None] - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)

    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, vf)
    return out.reshape(B, Sq, H, D)
