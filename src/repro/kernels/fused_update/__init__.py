from repro.kernels.fused_update.ops import sgd_update, tree_sgd_update

__all__ = ["sgd_update", "tree_sgd_update"]
