"""Fused momentum-SGD update — Pallas TPU kernel.

The parameter update is the memory-bound inner loop of Local SGD (executed
k_s times between communication rounds). Unfused, XLA issues separate
read/write passes for m' and p' (5 tensor streams + intermediates); the fused
kernel streams p, m, g through VMEM once per tile: 3 reads + 2 writes, the
bandwidth lower bound.

Tiling: flat 1-D view, 8×128-aligned blocks sized to keep three f32 tiles in
VMEM comfortably (block 64k elems → 3×256 KiB in-flight).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _upd_kernel(p_ref, m_ref, g_ref, po_ref, mo_ref, *, eta, beta, wd):
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    if wd:
        g = g + wd * p
    m2 = beta * m + g
    p2 = p - eta * m2
    po_ref[...] = p2.astype(po_ref.dtype)
    mo_ref[...] = m2.astype(mo_ref.dtype)


def fused_sgd_update(p, m, g, *, eta: float, beta: float = 0.0,
                     wd: float = 0.0, block: int = 65536,
                     interpret: bool = False):
    """Flat fused update. p/m/g: same shape; returns (p', m')."""
    shape, dtype_p, dtype_m = p.shape, p.dtype, m.dtype
    n = p.size
    pad = (-n) % block
    flat = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, 128)
    rows = (n + pad) // 128
    brows = block // 128
    grid = (rows // brows,)

    kernel = functools.partial(_upd_kernel, eta=eta, beta=beta, wd=wd)
    po, mo = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((brows, 128), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((brows, 128), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, 128), dtype_p),
                   jax.ShapeDtypeStruct((rows, 128), dtype_m)],
        interpret=interpret,
    )(flat(p), flat(m), flat(g))
    unflat = lambda x, dt: x.reshape(-1)[:n].reshape(shape).astype(dt)
    return unflat(po, dtype_p), unflat(mo, dtype_m)
