"""Jit'd wrapper for the fused update kernel + pytree-level API."""
from __future__ import annotations

import jax

from repro.kernels.fused_update import ref as R
from repro.kernels.fused_update.kernel import fused_sgd_update


def sgd_update(p, m, g, *, eta: float, beta: float = 0.0, wd: float = 0.0,
               impl: str = "interpret"):
    """Single-leaf fused momentum-SGD update."""
    if impl == "xla":
        return R.sgd_update_ref(p, m, g, eta=eta, beta=beta, wd=wd)
    return fused_sgd_update(p, m, g, eta=eta, beta=beta, wd=wd,
                            interpret=impl == "interpret")


def tree_sgd_update(params, moments, grads, *, eta, beta=0.0, wd=0.0,
                    impl: str = "interpret"):
    """Fused update over a whole parameter pytree."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_m = treedef.flatten_up_to(moments)
    flat_g = treedef.flatten_up_to(grads)
    out_p, out_m = [], []
    for p, m, g in zip(flat_p, flat_m, flat_g):
        p2, m2 = sgd_update(p, m, g, eta=eta, beta=beta, wd=wd, impl=impl)
        out_p.append(p2)
        out_m.append(m2)
    return treedef.unflatten(out_p), treedef.unflatten(out_m)
