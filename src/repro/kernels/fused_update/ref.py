"""Oracle for the fused Local-SGD update kernels.

``sgd_update_ref``: the momentum-SGD update each client runs k times per
communication round (Alg. 1 line 7):
    m' = β·m + g (+ wd·p);  p' = p − η·m'

``avg_update_ref``: the communication-round fusion (Alg. 1 line 5): average
N client replicas (already reduced to a sum by the all-reduce) and rebroadcast
— fused as one scale pass.
"""
from __future__ import annotations

import jax.numpy as jnp


def sgd_update_ref(p, m, g, *, eta: float, beta: float = 0.0, wd: float = 0.0):
    g32 = g.astype(jnp.float32)
    if wd:
        g32 = g32 + wd * p.astype(jnp.float32)
    m2 = beta * m.astype(jnp.float32) + g32
    p2 = p.astype(jnp.float32) - eta * m2
    return p2.astype(p.dtype), m2.astype(m.dtype)


def avg_update_ref(psum, n: int):
    return (psum.astype(jnp.float32) / n).astype(psum.dtype)
