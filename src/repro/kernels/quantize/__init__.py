from repro.kernels.quantize.ops import (
    compute_scale,
    dequant_mean,
    qmax_for,
    quantize,
)

__all__ = ["compute_scale", "dequant_mean", "qmax_for", "quantize"]
