from repro.kernels.quantize.ops import (
    INT8_TILE,
    check_tile_alignment,
    compute_scale,
    dequant_mean,
    qmax_for,
    quantize,
)

__all__ = ["INT8_TILE", "check_tile_alignment", "compute_scale",
           "dequant_mean", "qmax_for", "quantize"]
