"""Fused quantize / dequantize-accumulate — Pallas kernels.

The compressed communication round has two memory-bound halves:

  * client side: scale + stochastic-round + clip + narrow-cast of the local
    model (f32 -> int8 codes). Unfused, XLA materialises the scaled f32
    intermediate and the U[0,1) floats; the kernel streams x and the raw
    uint32 bits through VMEM once and writes codes directly.

  * server side: dequantize N client messages and reduce them to the
    consensus mean. Fused, each int8 tile is read once, widened in-register,
    weighted by its client scale and accumulated — no (N, M) f32
    intermediate ever hits HBM.

Tiling mirrors ``fused_update``: flat 1-D view, 128-lane blocks. Random bits
are *passed in* (jax.random outside) rather than drawn from the on-core PRNG
so the kernel is deterministic, CPU-interpretable, and bit-exact against
``ref.py``. int8 TPU tiles want (32, 128) alignment; the flat view is padded
to the block size so compiled mode sees aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INV_2_32 = 1.0 / 4294967296.0

# int8 arrays tile as (32, 128) on TPU (4× the f32 (8, 128) sublane
# packing). Interpret mode happily runs any block shape, which would let
# misaligned tilings hide until a compiled run — the kernels therefore
# refuse blocks that don't pad the flat (rows, 128) view to whole tiles.
# Single validator for every entry point (ops.py re-exports it).
INT8_TILE = (32, 128)


def check_tile_alignment(block: int) -> int:
    """Validate a flat block size against the int8 (32, 128) TPU tile.

    The kernels view their operands as (block/128, 128) lane blocks; int8
    stores want the row count to be a multiple of 32, so ``block`` must be
    a positive multiple of 32·128 = 4096 elements. Returns ``block``.
    """
    tile = INT8_TILE[0] * INT8_TILE[1]
    if block <= 0 or block % tile:
        raise ValueError(
            f"quantize kernels tile int8 as {INT8_TILE}: block={block} must "
            f"be a positive multiple of {tile} so padded inputs land on "
            f"whole tiles (interpret mode would accept it; a compiled TPU "
            f"run would not)")
    return block


def _quant_kernel(x_ref, r_ref, s_ref, q_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[0, 0]
    y = x / s * qmax
    u = r_ref[...].astype(jnp.float32) * _INV_2_32
    q = jnp.floor(y + u)
    q_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def quantize_kernel(x, rand_bits, scale, *, bits: int = 8,
                    block: int = 65536, interpret: bool = False):
    """x: any-shape f32; rand_bits: uint32 same shape; scale: () f32.

    Returns int8 codes, same shape as x.
    """
    qmax = float(2 ** (bits - 1) - 1)
    shape, n = x.shape, x.size
    pad = (-n) % check_tile_alignment(block)
    flat = lambda a: jnp.pad(a.reshape(-1), (0, pad)).reshape(-1, 128)
    rows = (n + pad) // 128
    brows = block // 128

    q = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(rows // brows,),
        in_specs=[pl.BlockSpec((brows, 128), lambda i: (i, 0)),
                  pl.BlockSpec((brows, 128), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((brows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.int8),
        interpret=interpret,
    )(flat(x.astype(jnp.float32)), flat(rand_bits),
      jnp.asarray(scale, jnp.float32).reshape(1, 1))
    return q.reshape(-1)[:n].reshape(shape)


def _deq_kernel(q_ref, s_ref, o_ref, *, qmax, inv_n):
    q = q_ref[...].astype(jnp.float32)          # (N, brows, 128)
    w = (s_ref[...].astype(jnp.float32) / qmax)  # (N, 1)
    o_ref[...] = jnp.sum(q * w[:, :, None], axis=0) * inv_n


def dequant_mean_kernel(q, scales, *, bits: int = 8, block: int = 65536,
                        interpret: bool = False):
    """q: (N, ...) int8 codes; scales: (N,) f32. Returns f32 mean of q[0]'s shape."""
    qmax = float(2 ** (bits - 1) - 1)
    N = q.shape[0]
    shape = q.shape[1:]
    n = q[0].size
    pad = (-n) % check_tile_alignment(block)
    qf = jnp.pad(q.reshape(N, -1), ((0, 0), (0, pad))).reshape(N, -1, 128)
    rows = (n + pad) // 128
    brows = block // 128

    out = pl.pallas_call(
        functools.partial(_deq_kernel, qmax=qmax, inv_n=1.0 / N),
        grid=(rows // brows,),
        in_specs=[pl.BlockSpec((N, brows, 128), lambda i: (0, i, 0)),
                  pl.BlockSpec((N, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((brows, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 128), jnp.float32),
        interpret=interpret,
    )(qf, scales.astype(jnp.float32).reshape(N, 1))
    return out.reshape(-1)[:n].reshape(shape)
