"""Jit'd wrappers for the quantize kernels + leaf-level API.

``impl`` follows the fused_update convention:
  "xla"       — pure-jnp oracle (fast on CPU, used inside the simulator)
  "interpret" — Pallas kernel, interpreter mode (CI / CPU parity)
  "pallas"    — Pallas kernel, compiled (TPU)

The kernels refuse ``block`` sizes that don't pad the flat view to whole
int8 (32, 128) TPU tiles — interpret mode would tolerate them, a compiled
run would not. ``check_tile_alignment`` / ``INT8_TILE`` (re-exported from
``kernel``) are the single validator every entry point shares.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quantize import ref as R
from repro.kernels.quantize.kernel import (
    INT8_TILE,
    check_tile_alignment,
    dequant_mean_kernel,
    quantize_kernel,
)

qmax_for = R.qmax_for


def compute_scale(x, *, eps: float = 1e-12):
    """Symmetric per-tensor scale: max|x|, floored away from zero."""
    return jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), eps)


def quantize(x, rand_bits, scale, *, bits: int = 8, impl: str = "xla",
             block: int = 65536):
    """Stochastic-rounding quantize one leaf to int8 codes."""
    if impl == "xla":
        return R.quantize_ref(x, rand_bits, scale, bits=bits)
    return quantize_kernel(x, rand_bits, scale, bits=bits, block=block,
                           interpret=impl == "interpret")


def dequant_mean(q, scales, *, bits: int = 8, impl: str = "xla",
                 block: int = 65536):
    """Fused dequantize + average of N stacked client messages."""
    if impl == "xla":
        return R.dequant_mean_ref(q, scales, bits=bits)
    return dequant_mean_kernel(q, scales, bits=bits, block=block,
                               interpret=impl == "interpret")


# ---------------------------------------------------------------------------
# Per-leaf path — the unit the streaming reduce pipelines
# ---------------------------------------------------------------------------
#
# A streaming round reduces the model one leaf at a time (engine.StreamingStar
# / local_sgd.build_sync_step(streaming=True)), so the ops layer exposes the
# two halves of ONE leaf's compressed round as self-contained calls: XLA can
# schedule leaf l's encode/decode concurrently with other leaves' compute
# instead of waiting for a whole-tree compression. Both halves dispatch to
# the same kernels (or the jnp oracle) as the tree-level entry points, so
# streaming and blocking rounds are bit-exact.

def encode_leaf(y, rand_bits, scales, *, bits: int = 8, impl: str = "xla",
                block: int = 65536):
    """Client half of one leaf's round: SR-quantize an (N, M) delta block.

    ``y``: f32 (N, M) per-client deltas (flattened leaf); ``rand_bits``:
    uint32 (N, M); ``scales``: f32 (N,) per-client symmetric scales.
    Returns int8 codes of ``y``'s shape.
    """
    if impl == "xla":
        return R.quantize_ref(y, rand_bits, scales[:, None], bits=bits)
    return jnp.stack([
        quantize(y[j], rand_bits[j], scales[j], bits=bits, impl=impl,
                 block=block)
        for j in range(y.shape[0])])


def decode_mean_leaf(q, scales, *, bits: int = 8, impl: str = "xla",
                     block: int = 65536):
    """Server half of one leaf's round: fused dequantize + mean.

    ``q``: int8 (N, M) codes; ``scales``: f32 (N,). Returns
    ``(deq, mean)`` — each client's dequantized f32 message (N, M), needed
    for the error-feedback residual, and their average (M,).
    """
    qmax = R.qmax_for(bits)
    mean = dequant_mean(q, scales, bits=bits, impl=impl, block=block)
    deq = q.astype(jnp.float32) * (scales[:, None] / qmax)
    return deq, mean
