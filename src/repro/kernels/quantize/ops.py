"""Jit'd wrappers for the quantize kernels + leaf-level API.

``impl`` follows the fused_update convention:
  "xla"       — pure-jnp oracle (fast on CPU, used inside the simulator)
  "interpret" — Pallas kernel, interpreter mode (CI / CPU parity)
  "pallas"    — Pallas kernel, compiled (TPU)

The kernels refuse ``block`` sizes that don't pad the flat view to whole
int8 (32, 128) TPU tiles — interpret mode would tolerate them, a compiled
run would not. ``check_tile_alignment`` / ``INT8_TILE`` (re-exported from
``kernel``) are the single validator every entry point shares.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quantize import ref as R
from repro.kernels.quantize.kernel import (
    INT8_TILE,
    check_tile_alignment,
    dequant_mean_kernel,
    quantize_kernel,
)

qmax_for = R.qmax_for


def compute_scale(x, *, eps: float = 1e-12):
    """Symmetric per-tensor scale: max|x|, floored away from zero."""
    return jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), eps)


def quantize(x, rand_bits, scale, *, bits: int = 8, impl: str = "xla",
             block: int = 65536):
    """Stochastic-rounding quantize one leaf to int8 codes."""
    if impl == "xla":
        return R.quantize_ref(x, rand_bits, scale, bits=bits)
    return quantize_kernel(x, rand_bits, scale, bits=bits, block=block,
                           interpret=impl == "interpret")


def dequant_mean(q, scales, *, bits: int = 8, impl: str = "xla",
                 block: int = 65536):
    """Fused dequantize + average of N stacked client messages."""
    if impl == "xla":
        return R.dequant_mean_ref(q, scales, bits=bits)
    return dequant_mean_kernel(q, scales, bits=bits, block=block,
                               interpret=impl == "interpret")
