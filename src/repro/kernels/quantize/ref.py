"""Oracles for the communication-compression kernels.

``quantize_ref``: symmetric linear quantization to ``bits``-bit signed codes
with *stochastic rounding* — the client-side half of a compressed
communication round (comm.QuantizedMean):

    qmax = 2^(bits-1) - 1
    y    = x / scale * qmax
    q    = clip(floor(y + u), -qmax, qmax)        u ~ U[0,1) from rand_bits

Stochastic rounding keeps the quantizer unbiased (E[q·scale/qmax] = x), which
is what the error-feedback convergence argument needs.

``dequant_mean_ref``: the server-side half — dequantize N client messages and
average them in one pass:

    mean = (1/N) Σ_i q_i · (scale_i / qmax)

Both are written with the *same* op order as the Pallas kernels so
ops-vs-ref parity is bit-exact given the same random bits.
"""
from __future__ import annotations

import jax.numpy as jnp

_INV_2_32 = 1.0 / 4294967296.0  # uint32 bits -> U[0,1)


def qmax_for(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def quantize_ref(x, rand_bits, scale, *, bits: int = 8):
    """x: f32 array; rand_bits: uint32, same shape; scale: scalar f32 (>0).

    Returns int8 codes in [-qmax, qmax].
    """
    qmax = qmax_for(bits)
    y = x.astype(jnp.float32) / scale * qmax
    u = rand_bits.astype(jnp.float32) * _INV_2_32
    q = jnp.floor(y + u)
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequant_mean_ref(q, scales, *, bits: int = 8):
    """q: (N, ...) int8 codes; scales: (N,) f32. Returns f32 mean, shape q[0]."""
    qmax = qmax_for(bits)
    n = q.shape[0]
    w = (scales.astype(jnp.float32) / qmax).reshape((n,) + (1,) * (q.ndim - 1))
    return jnp.sum(q.astype(jnp.float32) * w, axis=0) * (1.0 / n)
