from repro.kernels.ssd.ops import ssd

__all__ = ["ssd"]
