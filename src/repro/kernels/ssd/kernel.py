"""Mamba2 SSD — chunked state-space scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the GPU kernel's
warp-level scan is replaced by the matmul-dual form — per chunk, the
intra-chunk contribution is two (Q,Q)/(Q,N) matmuls on the MXU and the
inter-chunk recurrence carries a (P,N) state in VMEM scratch across the
sequential innermost grid axis (same accumulator pattern as flash attention).

Grid: (batch, head, n_chunks); chunk axis innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref,
                *, nc: int, Q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    A = a_ref[0].astype(jnp.float32)             # scalar for this head
    B = b_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)
    C = c_ref[0, :, 0, :].astype(jnp.float32)    # (Q, N)

    dA = dt * A                                  # (Q,)
    cums = jnp.cumsum(dA)                        # inclusive
    # intra-chunk decay matrix L[i,j] = exp(cums_i - cums_j) for i >= j
    diff = cums[:, None] - cums[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(qi >= kj, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                        # (Q, P)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # off-diagonal: contribution of the state entering this chunk
    state = state_ref[...]                       # (P, N)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q,N)·(P,N)ᵀ -> (Q,P)

    # state update: decay full chunk + inject dt-weighted inputs
    seg_end = jnp.exp(cums[-1] - cums)           # (Q,)
    inj = jax.lax.dot_general(xdt * seg_end[:, None], B,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state * jnp.exp(cums[-1]) + inj

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_ref[0, 0, :, :] = state_ref[...].astype(st_ref.dtype)


def ssd_chunked_kernel(x, dt, A, B, C, *, chunk: int = 128,
                       interpret: bool = False):
    """x: (b,S,H,P)  dt: (b,S,H)  A: (H,)  B,C: (b,S,G,N); G must divide H.

    Returns (y (b,S,H,P) fp32-accurate in x.dtype, final_state (b,H,P,N) f32).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda i, h, c: (i, c, h)),
            pl.BlockSpec((1,), lambda i, h, c: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda i, h, c: (i, c, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda i, h, c: (i, c, h // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, st


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
