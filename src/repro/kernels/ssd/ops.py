"""Public wrapper for the SSD kernel."""
from __future__ import annotations

from repro.kernels.ssd import ref as R
from repro.kernels.ssd.kernel import ssd_chunked_kernel


def ssd(x, dt, A, B, C, *, chunk: int = 128, impl: str = "interpret"):
    """Dispatch: "pallas" (TPU) | "interpret" (CPU validation) | "xla" (oracle)."""
    if impl == "xla":
        y, st = R.ssd_ref(x, dt, A, B, C)
        return y.astype(x.dtype), st
    return ssd_chunked_kernel(x, dt, A, B, C, chunk=chunk,
                              interpret=impl == "interpret")
