"""Oracle for the SSD kernel: the exact sequential state-space recurrence.

    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t

Deliberately the *recurrent* form (not the chunked dual) so the kernel and
the model's chunked implementation are both checked against independent math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """x: (b,S,H,P)  dt: (b,S,H)  A: (H,)  B,C: (b,S,G,N) with G dividing H.
    Returns y (b,S,H,P) fp32, final_state (b,H,P,N) fp32."""
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (b,S,H,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (b,H,P), (b,H), (b,H,N), (b,H,N)
        decay = jnp.exp(dtt * A[None, :])[..., None, None]  # (b,H,1,1)
        h = h * decay + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    h0 = (jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
