import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers AND compiles.

For each cell we lower + compile the relevant step programs against
ShapeDtypeStruct inputs (zero allocation), print memory/cost analysis and
parse collective traffic per mesh axis, then write a JSON artifact consumed
by benchmarks/roofline.py and EXPERIMENTS.md.

Programs per cell:
  train_4k     → local_step   (Local SGD inner step: NO client-axis comm)
                 sync_step    (Alg.1 line 5: the parameter-averaging round)
                 syncsgd_step (baseline: grads all-reduced every step)
  prefill_32k  → prefill_step
  decode_32k / long_500k → serve_step (one token vs seq_len-sized cache)

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # the full matrix
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.specs import input_specs
from repro.core import local_sgd as LS
from repro.core import serving as SV


def mesh_shape_dict(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _analyse(name, lowered, mesh, verbose=True):
    compiled = lowered.compile()
    txt = compiled.as_text()
    colls = H.parse_collectives_nested(txt, mesh_shape_dict(mesh))
    rec = {
        "program": name,
        "memory": H.memory_summary(compiled),
        "cost": H.cost_summary(compiled),  # NB: loop bodies counted once
        "collectives": H.collective_summary(colls),  # loop-weighted
    }
    if verbose:
        mem = rec["memory"]
        print(f"  [{name}] peak_bytes/device={mem.get('peak_bytes')} "
              f"flops={rec['cost'].get('flops'):.3e} "
              f"hbm_bytes={rec['cost'].get('bytes_accessed'):.3e} "
              f"coll_link_bytes={rec['collectives']['total_link_bytes']:.3e} "
              f"by_axes={rec['collectives']['by_axes']}")
    return rec


def dryrun_cell(arch: str, shape_name: str, mesh, *, verbose=True,
                hierarchical=False, microbatch=4, programs=None,
                overrides=None, donate=False):
    """Lower+compile all programs for one (arch, shape, mesh) cell."""
    t0 = time.time()
    kind, cfg, *rest = (lambda r: (r[0], r[1], *r[2:]))(  # unpack
        input_specs(arch, shape_name, mesh, overrides=overrides))
    records = []
    want = lambda p: programs is None or p in programs
    with mesh_context(mesh):
        if kind == "train":
            state, batch, st_sh, b_sh, client_axis = rest
            if hierarchical and "pod" in mesh.axis_names:
                from repro.launch.specs import train_specs
                state, batch, st_sh, b_sh, client_axis = train_specs(
                    cfg, SHAPES[shape_name], mesh, client_axis="pod")
            local_step, sync_step, _ = LS.build_train_steps(
                cfg, mesh, client_axis=client_axis, microbatch=microbatch)
            if want("local_step"):
                jl = jax.jit(local_step, in_shardings=(st_sh, b_sh, None),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,) if donate else ())
                records.append(_analyse(
                    "local_step", jl.lower(state, batch, 0.1), mesh, verbose))

            if want("sync_step"):
                js = jax.jit(sync_step, in_shardings=(st_sh,), out_shardings=st_sh)
                records.append(_analyse(
                    "sync_step", js.lower(state), mesh, verbose))

            # multi-pod meshes additionally prove the two-level round
            # lowers: dense intra-pod (data axis) + int8-EF inter-pod
            # (pod axis) — the collectives engine.Hierarchical prices
            if (want("sync_step_2level") and "pod" in mesh.axis_names
                    and not hierarchical):
                n_pods = mesh_shape_dict(mesh)["pod"]
                s2 = LS.build_sync_step("dense", hierarchical=True,
                                        n_pods=n_pods, inter_reducer="int8")
                # EF residuals join the state on the first sync; shardings
                # for the new "comm" key follow the params replica layout
                j2 = jax.jit(s2, in_shardings=(st_sh,))
                records.append(_analyse(
                    "sync_step_2level", j2.lower(state), mesh, verbose))

            # SyncSGD baseline: same step + gradient all-reduce over clients
            if want("syncsgd_step"):
                syncsgd_step, _, _ = LS.build_train_steps(
                    cfg, mesh, client_axis=client_axis, microbatch=microbatch,
                    sync_grads=True)
                jss = jax.jit(syncsgd_step, in_shardings=(st_sh, b_sh, None),
                              out_shardings=(st_sh, None))
                records.append(_analyse(
                    "syncsgd_step", jss.lower(state, batch, 0.1), mesh, verbose))
        else:
            sp = rest[0]
            if SHAPES[shape_name].mode == "prefill":
                step = SV.build_prefill_step(cfg)
                args = [sp["params"], sp["cache"], sp["tokens"]]
                shs = [sp["params_sh"], sp["cache_sh"], sp["tokens_sh"]]
                if cfg.frontend:
                    args.append(sp["frontend"])
                    shs.append(sp["frontend_sh"])
                jp = jax.jit(step, in_shardings=tuple(shs),
                             out_shardings=(None, sp["cache_sh"]))
                records.append(_analyse(
                    "prefill_step", jp.lower(*args), mesh, verbose))
            else:
                step = SV.build_serve_step(cfg)
                jd = jax.jit(step,
                             in_shardings=(sp["params_sh"], sp["cache_sh"],
                                           sp["tokens_sh"]),
                             out_shardings=(None, sp["cache_sh"]),
                             donate_argnums=(1,) if donate else ())
                records.append(_analyse(
                    "serve_step",
                    jd.lower(sp["params"], sp["cache"], sp["tokens"]),
                    mesh, verbose))
    return {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_shape_dict(mesh),
        "hierarchical": hierarchical,
        "arch_variant": cfg.name,
        "elapsed_s": round(time.time() - t0, 1),
        "programs": records,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--hierarchical", action="store_true",
                    help="pod-level clients (beyond-paper mode)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--programs", default=None, help="comma-sep subset")
    ap.add_argument("--kv-int8", action="store_true", help="int8 KV cache variant")
    ap.add_argument("--donate", action="store_true",
                    help="donate state/cache buffers (in-place update)")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    os.makedirs(args.out, exist_ok=True)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        print(f"=== dryrun {arch} × {shape} × {tag} ===", flush=True)
        try:
            rec = dryrun_cell(arch, shape, mesh, hierarchical=args.hierarchical,
                              microbatch=args.microbatch,
                              programs=args.programs.split(',') if args.programs else None,
                              overrides={"kv_quant": True} if args.kv_int8 else None,
                              donate=args.donate)
            suffix = ("_hier" if args.hierarchical else "") + ("_kvint8" if args.kv_int8 else "") + ("_donate" if args.donate else "")
            fname = f"{args.out}/{arch}_{shape}_{tag}{suffix}.json"
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {fname} ({rec['elapsed_s']}s)", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL CELLS OK")


if __name__ == "__main__":
    main()
