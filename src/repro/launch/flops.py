"""Analytical FLOPs / bytes model per (arch × shape).

XLA's HloCostAnalysis counts while-loop bodies once (scans: layer stacks,
microbatch, q-chunks), so the roofline's compute/memory terms use this
analytical model; the HLO numbers are reported alongside as a cross-check.

Conventions: 1 MAC = 2 FLOPs; training = fwd + 2×bwd (+⅓ remat recompute →
×4 fwd-equivalents with full activation checkpointing); attention FLOPs use
the true masked pair count (causal ½, window bands); MoE counts only routed
(active) experts + shared experts — MODEL_FLOPS = 6·N_active·D convention.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import padded_vocab


@dataclass
class FlopsReport:
    n_params: float            # total parameters
    n_active: float            # active per token (MoE: routed top-k + shared)
    fwd_flops: float           # one forward pass, all tokens, global
    step_flops: float          # the lowered program (train: fwd+bwd+remat)
    model_flops: float         # 6·N_active·D (train) or 2·N_active·D (decode)
    hbm_bytes: float           # param + activation traffic estimate, global
    breakdown: dict


def _attn_pairs(S: int, window, kind: str) -> float:
    """Masked (q,k) pair count per sequence for one layer."""
    if kind == "decode":
        return float(min(S, window) if window else S)
    if window and window < S:
        return float(window) * S - window * (window - 1) / 2.0
    return S * (S + 1) / 2.0


def count_params(cfg: ArchConfig) -> tuple[float, float]:
    """(total, active-per-token) parameter counts."""
    d = cfg.d_model
    vp = padded_vocab(cfg)
    att = cfg.attention
    total = vp * d  # embed
    if not cfg.tie_embeddings:
        total += d * vp
    per_layer_attn = 0.0
    if att:
        if att.kind == "gqa":
            per_layer_attn = d * att.n_heads * att.head_dim * 2 \
                + d * att.n_kv_heads * att.head_dim * 2
        else:
            qk = att.qk_nope_head_dim + att.qk_rope_head_dim
            q_in = (d * att.q_lora_rank + att.q_lora_rank * att.n_heads * qk) \
                if att.q_lora_rank else d * att.n_heads * qk
            per_layer_attn = (q_in + d * (att.kv_lora_rank + att.qk_rope_head_dim)
                              + att.kv_lora_rank * att.n_heads
                              * (att.qk_nope_head_dim + att.v_head_dim)
                              + att.n_heads * att.v_head_dim * d)
    dense_mlp = 3 * d * cfg.d_ff
    moe = cfg.moe
    total_active = 0.0
    kinds = cfg.layer_kinds()
    n_head_dense = moe.n_dense_layers if moe else 0
    for li, kind in enumerate(kinds):
        if kind in ("G", "L"):
            total += per_layer_attn
            total_active += per_layer_attn
            if moe and li >= n_head_dense:
                router = d * moe.n_experts
                expert = 3 * d * moe.d_expert
                shared = 3 * d * moe.n_shared * moe.d_expert
                total += router + moe.n_experts * expert + shared
                total_active += router + moe.top_k * expert + shared
            else:
                total += dense_mlp
                total_active += dense_mlp
        elif kind == "M":
            ssm = cfg.ssm
            d_inner = ssm.expand * d
            nh = d_inner // ssm.head_dim
            gN = ssm.n_groups * ssm.d_state
            w = d * (2 * d_inner + 2 * gN + nh) + d_inner * d
            total += w
            total_active += w
        elif kind == "R":
            lru = cfg.rglru.lru_width or d
            w = d * lru * 2 + lru * lru * 2 + lru * d + dense_mlp
            total += w
            total_active += w
    total_active += vp * d / max(1, 1)  # unembed matmul params touched
    return total, total_active


def shape_flops(cfg: ArchConfig, shape: ShapeConfig) -> FlopsReport:
    d = cfg.d_model
    att = cfg.attention
    S = shape.seq_len
    B = shape.global_batch
    mode = shape.mode
    tokens = B * (1 if mode == "decode" else S)

    n_params, n_active = count_params(cfg)

    # matmul flops: 2 × active params per token (excl. embed lookup)
    mm = 2.0 * (n_active - padded_vocab(cfg) * d) * tokens
    # unembed
    mm += 2.0 * padded_vocab(cfg) * d * tokens

    # attention score+value flops per layer
    attn = 0.0
    kinds = cfg.layer_kinds()
    for kind in kinds:
        if kind in ("G", "L") and att:
            window = att.window if kind == "L" else None
            pairs = _attn_pairs(S, window, "decode" if mode == "decode" else "full")
            hd_qk = (att.qk_nope_head_dim + att.qk_rope_head_dim
                     if att.kind == "mla" else att.head_dim)
            hd_v = att.v_head_dim if att.kind == "mla" else att.head_dim
            attn += 2.0 * att.n_heads * pairs * (hd_qk + hd_v) * B
        elif kind == "M":
            ssm = cfg.ssm
            d_inner = ssm.expand * d
            # SSD: intra-chunk 'attention' + state path ≈ 2·S·d_inner·d_state·2
            attn += 4.0 * tokens * d_inner * ssm.d_state
        elif kind == "R":
            lru = cfg.rglru.lru_width or d
            attn += 10.0 * tokens * lru  # elementwise recurrence, negligible

    fwd = mm + attn
    if mode == "train":
        step = 4.0 * fwd  # fwd + 2×bwd + ~1×remat recompute
        model_flops = 6.0 * n_active * tokens
    else:
        step = fwd
        model_flops = 2.0 * n_active * tokens

    # HBM traffic: params once (bf16) + activations (rough: 12 streams of
    # (tokens × d) bf16 per layer) + KV cache traffic for decode
    act = 12.0 * tokens * d * 2.0 * len(kinds)
    param_bytes = n_params * 2.0 * (3 if mode == "train" else 1)
    kv = 0.0
    if mode == "decode" and att:
        for kind in kinds:
            if kind not in ("G", "L"):
                continue
            window = att.window if kind == "L" else None
            eff = min(S, window) if window else S
            if att.kind == "mla":
                kv += B * eff * (att.kv_lora_rank + att.qk_rope_head_dim) * 2.0
            else:
                kv += B * eff * att.n_kv_heads * att.head_dim * 2.0 * 2.0
    hbm = param_bytes + act + kv

    return FlopsReport(
        n_params=n_params, n_active=n_active, fwd_flops=fwd, step_flops=step,
        model_flops=model_flops, hbm_bytes=hbm,
        breakdown={"matmul": mm, "attn": attn, "kv_bytes": kv,
                   "param_bytes": param_bytes, "act_bytes": act})
