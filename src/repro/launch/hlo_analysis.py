"""Post-SPMD HLO analysis: collective bytes per mesh axis + roofline terms.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic, so we parse the partitioned HLO text: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op contributes its tensor
bytes, attributed to the mesh axes its replica groups span (this is how we
separate the paper's client-axis traffic from tensor-parallel traffic).

Link-traffic factors (ring algorithms, large N): all-reduce moves ≈2× its
bytes over the busiest link; all-gather / reduce-scatter ≈1× the full tensor;
all-to-all ≈1×(N-1)/N; collective-permute 1×.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_KIND_RE = re.compile(
    r"(?<!%)\b(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?(?P<done>-done)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(.*?)\}\s*[,)]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")

_FACTORS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_group(line: str, n_devices: int) -> Optional[List[int]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(g, s)
        return list(ids[0])
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if not first:
            return None
        return [int(x) for x in first.split(",") if x.strip()]
    m = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", line)
    if m:  # collective-permute: attribute by its first (src, dst) pair
        return [int(m.group(1)), int(m.group(2))]
    return None


def _axes_of_group(group: List[int], mesh_shape: Dict[str, int]) -> Tuple[str, ...]:
    """Which mesh axes vary within a replica group (device-id major order =
    mesh axis order, matching jax.make_mesh's default device assignment)."""
    names = list(mesh_shape)
    sizes = [mesh_shape[n] for n in names]
    strides = {}
    acc = 1
    for n, s in zip(reversed(names), reversed(sizes)):
        strides[n] = acc
        acc *= s
    coords = []
    for d in group:
        c = {}
        for n in names:
            c[n] = (d // strides[n]) % mesh_shape[n]
        coords.append(c)
    varying = tuple(n for n in names
                    if len({c[n] for c in coords}) > 1)
    return varying


def parse_collectives(hlo_text: str, mesh_shape: Dict[str, int]) -> List[dict]:
    """Per-collective {kind, bytes, link_bytes, axes} from partitioned HLO."""
    n_devices = math.prod(mesh_shape.values())
    out = []
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        m = _KIND_RE.search(line)
        if not m or m.group("done"):
            continue
        kind = m.group("kind")
        # output type(s) = everything between '=' and the op keyword;
        # covers scalar and tuple-typed (variadic) collectives.
        outtype = line.split(" = ", 1)[1][: m.start() - line.index(" = ") - 3]
        nbytes = _shape_bytes(outtype)
        group = _first_group(line, n_devices)
        axes = _axes_of_group(group, mesh_shape) if group else ("unknown",)
        n = len(group) if group else 1
        factor = _FACTORS[kind]
        if kind == "all-reduce":
            link = 2.0 * nbytes * (n - 1) / max(n, 1)
        elif kind in ("all-gather", "reduce-scatter"):
            link = nbytes * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            link = nbytes * (n - 1) / max(n, 1)
        else:
            link = float(nbytes)
        out.append({"kind": kind, "bytes": nbytes, "link_bytes": link,
                    "group_size": n, "axes": list(axes)})
    return out


# ---------------------------------------------------------------------------
# Loop-aware accounting.
#
# XLA's cost analysis (and a naive text scan) counts a while-loop body ONCE,
# but jax.lax.scan bodies execute trip-count times — layer stacks, microbatch
# accumulation and q-chunked attention all live in scans here. We therefore
# walk the HLO call graph: split the module into computations, find `while`
# ops, recover the trip count from the loop condition's comparison constant,
# and multiply everything inside by the product of enclosing trip counts.
# ---------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)?.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    comps: Dict[str, List[str]] = {}
    cur = None
    entry_name = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        is_header = (line and not line[0].isspace() and stripped.endswith("{")
                     and not line.startswith("HloModule"))
        if is_header:
            toks = stripped.split()
            is_entry = toks[0] == "ENTRY"
            name_tok = toks[1] if is_entry else toks[0]
            cur = name_tok.lstrip("%").split("(")[0].rstrip(",")
            comps[cur] = []
            if is_entry:
                entry_name = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry_name


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for l in cond_lines for c in _CONST_RE.findall(l)]
    return max(consts) if consts else 1


def parse_collectives_nested(hlo_text: str, mesh_shape: Dict[str, int]
                             ) -> List[dict]:
    """Like parse_collectives but weighted by enclosing scan trip counts."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return parse_collectives(hlo_text, mesh_shape)

    multiplier: Dict[str, float] = {}

    def visit(name: str, mult: float):
        if name not in comps:
            return
        if multiplier.get(name, 0.0) >= mult:
            return  # already visited at >= multiplicity
        multiplier[name] = mult
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                n = _trip_count(comps.get(cond, []))
                visit(body, mult * n)
                visit(cond, mult * n)
            else:
                for callee in _CALL_RE.findall(line):
                    if callee in comps and callee != name:
                        visit(callee, mult)

    visit(entry, 1.0)

    out = []
    for cname, lines in comps.items():
        mult = multiplier.get(cname)
        if mult is None:
            continue
        for c in _collectives_in_lines(lines, mesh_shape):
            c = dict(c)
            c["bytes"] *= mult
            c["link_bytes"] *= mult
            c["trip_mult"] = mult
            out.append(c)
    return out


def _collectives_in_lines(lines: List[str], mesh_shape: Dict[str, int]):
    return parse_collectives("\n".join(lines), mesh_shape)


def collective_summary(colls: List[dict]) -> dict:
    by_axes = defaultdict(float)
    by_kind = defaultdict(float)
    for c in colls:
        by_axes["+".join(c["axes"]) or "none"] += c["link_bytes"]
        by_kind[c["kind"]] += c["link_bytes"]
    return {"total_link_bytes": sum(c["link_bytes"] for c in colls),
            "count": len(colls),
            "by_axes": dict(by_axes), "by_kind": dict(by_kind)}


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        alias = getattr(ma, "alias_size_in_bytes", 0) or 0
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": alias,  # donated buffers (in-place update)
            "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0) or 0)
                          + (getattr(ma, "temp_size_in_bytes", 0) or 0)
                          + (getattr(ma, "output_size_in_bytes", 0) or 0)
                          - alias,
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def cost_summary(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                "transcendentals": float(ca.get("transcendentals", 0.0))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
