"""Production meshes (TPU v5e pods).

single-pod: (16, 16)   axes (data, model)   — 256 chips
multi-pod : (2, 16, 16) axes (pod, data, model) — 512 chips

Axis semantics (shared by sharding.rules and the engine topologies, see
docs/topologies.md):

  pod    inter-pod axis — one shard per pod, connected by the slow
         DCN/WAN links; the hop `engine.Hierarchical` compresses and the
         two-level sync round (`local_sgd.build_sync_step(
         hierarchical=True)`) crosses once per round.
  data   intra-pod client/batch axis — the paper's N clients live on the
         (pod × data) grid pod-major, so a leading client dim sharded
         ``P(("pod", "data"))`` puts each pod's clients on one contiguous
         slice and the intra-pod reduce on cheap ICI.
  model  tensor-parallel axis (heads / ffn / experts / vocab).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS for 512 host devices before any
jax import, everything else sees the real 1-CPU topology.
"""
from __future__ import annotations

import contextlib

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum itself) only exist on newer releases; older ones are Auto-only."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def mesh_context(mesh):
    """``jax.sharding.set_mesh(mesh)`` where available, else the classic
    ``with mesh:`` context (pre-0.5 jax has no set_mesh)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return _make_mesh((data, model), ("data", "model"))


def make_host_pod_mesh(pods: int = 2, data: int = 1, model: int = 1):
    """Small (pod, data, model) mesh for tests / CPU runs.

    The host-device miniature of the multi-pod production mesh: same axis
    names, so the two-level sync round and its HLO collective analysis run
    under ``--xla_force_host_platform_device_count`` exactly as they would
    on pods (requires ``pods * data * model`` host devices).
    """
    return _make_mesh((pods, data, model), ("pod", "data", "model"))


# v5e hardware constants for the roofline (per chip / per link). The α–β
# presets in comm/cost.py (``link_model("ici"/"dcn")``) are calibrated
# against ICI_BW / DCN_BW — converted to Gbit/s, with order-of-magnitude
# setup latencies — so modeled comm seconds in the benchmarks line up with
# the roofline's hardware model (units: B/s here, Gbit/s in NetworkModel;
# see docs/cost_model.md for the full units table).
PEAK_FLOPS_BF16 = 197e12   # FLOP/s
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link
DCN_BW = 6.25e9            # B/s per host link (inter-pod data-center network)
