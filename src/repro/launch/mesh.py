"""Production meshes (TPU v5e pods).

single-pod: (16, 16)   axes (data, model)   — 256 chips
multi-pod : (2, 16, 16) axes (pod, data, model) — 512 chips

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS for 512 host devices before any
jax import, everything else sees the real 1-CPU topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants for the roofline (per chip / per link)
PEAK_FLOPS_BF16 = 197e12   # FLOP/s
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link
