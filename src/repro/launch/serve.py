"""Serving launcher: batched prefill + decode with a sharded KV cache.

CPU-scale demo of the decode path the dry-run proves for the production mesh:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.serving import build_prefill_step, build_serve_step
from repro.models import transformer as TF
from repro.utils.logging import get_logger

log = get_logger("serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    params = TF.init_params(jax.random.key(args.seed), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    rng = np.random.RandomState(args.seed)
    prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, size=(B, P)), jnp.int32)
    frontend = None
    if cfg.frontend:
        frontend = jnp.asarray(
            rng.randn(B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)

    cache = TF.init_cache(cfg, B, P + G + (cfg.n_frontend_tokens if cfg.frontend else 0))
    prefill = jax.jit(build_prefill_step(cfg))
    serve = jax.jit(build_serve_step(cfg))

    t0 = time.time()
    if cfg.frontend:
        logits, cache = prefill(params, cache, prompt, frontend)
    else:
        logits, cache = prefill(params, cache, prompt)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t1 = time.time()
    for _ in range(G - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t1

    gen = jnp.concatenate(out, axis=1)
    log.info("arch=%s batch=%d prefill %d tok in %.3fs (%.0f tok/s); "
             "decode %d steps in %.3fs (%.1f tok/s/seq, %.1f total tok/s)",
             cfg.name, B, B * P, t_prefill, B * P / max(t_prefill, 1e-9),
             G, t_dec, (G - 1) / max(t_dec, 1e-9), B * (G - 1) / max(t_dec, 1e-9))
    log.info("sample generation[0,:16]: %s", np.asarray(gen[0, :16]).tolist())
    return gen


if __name__ == "__main__":
    main()
