"""Serving launcher — continuous batching under open-loop synthetic load.

Drives ``repro.serve.ServeEngine``: restore a checkpoint (or init fresh
params), generate a Poisson/bursty request trace, run the
continuous-batching decode loop, and print the latency/throughput report
(modeled roofline numbers next to measured host wall-clock).

Examples:
  # serve a trained checkpoint (arch comes from checkpoint meta)
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 8 --ckpt-out /tmp/ck
  PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/ck \
      --process bursty --rate 500 --requests 32

  # or serve fresh random params by arch name
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --requests 16 --trace /tmp/serve_trace.json
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.models import transformer as TF
from repro.obs import write_chrome_trace, write_jsonl
from repro.obs.metrics import MetricsRegistry
from repro.obs.series import SeriesRegistry
from repro.serve import (
    SchedulerConfig,
    ServeEngine,
    TrafficConfig,
    arrival_summary,
    generate_requests,
)
from repro.utils.logging import get_logger

log = get_logger("serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--ckpt", metavar="DIR",
                     help="checkpoint dir from launch/train.py --ckpt-out "
                          "(arch is read from checkpoint meta)")
    src.add_argument("--arch", help="serve fresh random params for this arch")
    ap.add_argument("--smoke", action="store_true")
    # traffic
    ap.add_argument("--process", default="poisson",
                    choices=["poisson", "bursty"])
    ap.add_argument("--rate", type=float, default=None, metavar="RPS",
                    help="offered arrival rate, modeled requests/s "
                         "(default: 0.7 × modeled capacity)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="mean prompt length (geometric)")
    ap.add_argument("--gen", type=int, default=8,
                    help="mean output length (geometric)")
    ap.add_argument("--burst-factor", type=float, default=8.0)
    # scheduler
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--prefills-per-step", type=int, default=1)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export the request/decode span timeline plus the "
                         "serve.* counter tracks (queue depth, batch "
                         "occupancy, tokens/s) as a Perfetto-loadable "
                         "Chrome trace (+ .jsonl log)")
    ap.add_argument("--profile", action="store_true",
                    help="wall-time the jitted prefill/decode steps "
                         "(block-until-ready) against the roofline prices "
                         "and print the modeled-vs-measured skew table")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="also bracket the run in a jax.profiler trace "
                         "session writing XPlane artifacts to DIR "
                         "(implies --profile)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    sched = SchedulerConfig(n_slots=args.slots, max_seq_len=args.max_seq_len,
                            max_queue=args.max_queue,
                            max_prefills_per_step=args.prefills_per_step)
    if args.ckpt:
        engine = ServeEngine.from_checkpoint(args.ckpt, scheduler=sched)
        cfg = engine.cfg
    else:
        cfg = get_arch(args.arch, smoke=args.smoke)
        params = TF.init_params(jax.random.key(args.seed), cfg)
        engine = ServeEngine(cfg, params, scheduler=sched)

    # default offered load: 70% of the modeled decode capacity, so the
    # out-of-the-box run sits below the knee of the latency curve
    capacity = sched.n_slots / engine.decode_step_s
    rate = args.rate if args.rate is not None else 0.7 * capacity
    mean_p, mean_g = args.prompt_len, args.gen
    tcfg = TrafficConfig(
        process=args.process, rate_rps=rate, n_requests=args.requests,
        mean_prompt_len=mean_p, max_prompt_len=min(4 * mean_p,
                                                   args.max_seq_len // 2),
        mean_out_len=mean_g, max_out_len=min(4 * mean_g,
                                             args.max_seq_len // 2),
        burst_factor=args.burst_factor, seed=args.seed)
    requests = generate_requests(tcfg, cfg.vocab_size)
    offered = arrival_summary(requests)
    log.info("arch=%s slots=%d capacity=%.0f tok/s offered=%.0f rps (%s)",
             cfg.name, sched.n_slots, capacity, offered["rate_rps"],
             args.process)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        from repro.utils.logging import RUN_ID
        tracer = Tracer(run_id=RUN_ID)
    registry = MetricsRegistry()
    series = SeriesRegistry()
    profile = None
    if args.profile or args.profile_dir:
        from repro.obs import ProfileSession
        profile = ProfileSession(logdir=args.profile_dir)
    if profile is not None:
        with profile:
            report = engine.run(requests, tracer=tracer, registry=registry,
                                series=series, profile=profile)
    else:
        report = engine.run(requests, tracer=tracer, registry=registry,
                            series=series)

    n_rej = len(report.rejected)
    log.info("served %d/%d requests (%d rejected), %d decode steps, "
             "mean occupancy %.2f/%d",
             len(report.completed), len(requests), n_rej, report.n_steps,
             report.mean_occupancy, sched.n_slots)
    log.info("modeled: makespan %.4fs, decode step %.2eS, %.0f tok/s | "
             "measured: %.2fs wall, %.0f tok/s",
             report.makespan_s, report.decode_step_s, report.modeled_tok_s,
             report.measured_wall_s, report.measured_tok_s)
    for name, s in report.latency_summary().items():
        log.info("  %-20s p50=%.2e p95=%.2e p99=%.2e (n=%d)", name,
                 s["p50"], s["p95"], s["p99"], s["count"])
    if profile is not None:
        from repro.obs import format_skew_table
        profile.emit_spans(tracer)
        print(format_skew_table(profile.skew_table()))
    if tracer is not None:
        write_chrome_trace(tracer, args.trace, series=series)
        write_jsonl(tracer, args.trace + "l")   # foo.json -> foo.jsonl
        log.info("trace_written", path=args.trace, spans=len(tracer.spans))
    return report


if __name__ == "__main__":
    main()
