"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape × mesh).

``input_specs`` builds weak-type-correct, shardable abstract inputs with NO
device allocation — the dry-run lowers/compiles against these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, arch_for_shape
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import local_sgd as LS
from repro.core import serving as SV
from repro.models import transformer as TF
from repro.sharding import param_specs
from repro.sharding.rules import cache_specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def client_axes_for(mesh) -> Tuple[str, ...]:
    """Paper-faithful client axes: every non-model axis (pod×data clients)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_clients_for(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in client_axes_for(mesh))


def train_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                client_axis=None, optimizer: str = "sgd"):
    """Returns (state_shapes, batch_shapes, state_shardings, batch_shardings)."""
    client_axis = client_axis or client_axes_for(mesh)
    if isinstance(client_axis, str):
        client_axis = (client_axis,)
    C = n_clients_for(mesh) if set(client_axis) == set(client_axes_for(mesh)) else \
        math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in client_axis)

    state = LS.init_state_shape(cfg, C, optimizer)
    B, S = shape.global_batch, shape.seq_len
    assert B % C == 0, (B, C)
    S_text = S - (cfg.n_frontend_tokens if cfg.frontend else 0)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    hierarchical = tuple(client_axis) == ("pod",)
    if hierarchical:
        # per-pod clients: batch additionally split over the intra-pod data
        # axis (SyncSGD within the pod) — (pod, data, b, S)
        n_data = sizes["data"]
        assert B % (C * n_data) == 0, (B, C, n_data)
        lead_shape = (C, n_data, B // (C * n_data))
        lead_spec = ("pod", "data", None)
    else:
        lead_shape = (C, B // C)
        lead_spec = (client_axis, None)
    batch = {
        "tokens": _sds(lead_shape + (S_text,), jnp.int32),
        "labels": _sds(lead_shape + (S_text,), jnp.int32),
    }
    if cfg.frontend:
        batch["frontend"] = _sds(
            lead_shape + (cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)

    ca = client_axis if len(client_axis) > 1 else client_axis[0]
    st_sh = LS.state_shardings(cfg, mesh, state["params"], state["opt"], ca)
    b_sh = {
        "tokens": NamedSharding(mesh, P(*lead_spec, None)),
        "labels": NamedSharding(mesh, P(*lead_spec, None)),
    }
    if cfg.frontend:
        b_sh["frontend"] = NamedSharding(mesh, P(*lead_spec, None, None))
    return state, batch, st_sh, b_sh, ca


def serve_specs(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (params_shape, cache_shape, tokens_shape, shardings...)."""
    B, S = shape.global_batch, shape.seq_len
    params = TF.init_params_shape(cfg)
    cache = jax.eval_shape(lambda: TF.init_cache(cfg, B, S))
    data_axes = client_axes_for(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data = math.prod(sizes[a] for a in data_axes)

    if B % n_data == 0:
        batch_axes, seq_axes = data_axes, ()
    else:
        # batch too small to shard (long_500k): sequence-shard the KV cache
        batch_axes, seq_axes = (), data_axes

    from repro.sharding.rules import feasible_specs

    pspecs = feasible_specs(param_specs(params, client_axis=None), params, mesh)
    cspecs = feasible_specs(
        cache_specs(cache, data_axes=batch_axes, seq_axes=seq_axes), cache, mesh)
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda s: isinstance(s, P))

    if shape.mode == "decode":
        tokens = _sds((B, 1), jnp.int32)
    else:
        S_text = S - (cfg.n_frontend_tokens if cfg.frontend else 0)
        tokens = _sds((B, S_text), jnp.int32)
    tok_spec = P(batch_axes if batch_axes else None, None)
    out = {
        "params": params, "cache": cache, "tokens": tokens,
        "params_sh": to_sh(pspecs), "cache_sh": to_sh(cspecs),
        "tokens_sh": NamedSharding(mesh, tok_spec),
    }
    if shape.mode == "prefill" and cfg.frontend:
        out["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        out["frontend_sh"] = NamedSharding(mesh, P(batch_axes if batch_axes else None, None, None))
    return out


def input_specs(arch_name: str, shape_name: str, mesh, overrides=None, **kw):
    """Unified entry: abstract inputs + shardings for one matrix cell."""
    shape = SHAPES[shape_name]
    cfg = arch_for_shape(arch_name, shape_name)
    if overrides:
        cfg = cfg.replace(**overrides)
    if shape.mode == "train":
        return ("train", cfg, *train_specs(cfg, shape, mesh, **kw))
    return ("serve", cfg, serve_specs(cfg, shape, mesh))
