"""Training launcher.

Runs STL-SGD (or a baseline) on an (arch × mesh) with synthetic LM data.
On this CPU container it drives reduced (smoke) configs end-to-end; on real
TPU pods the same code paths run the full configs (the dry-run proves they
lower/compile).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --algo stl_sc --eta1 0.05 --k1 4 --T1 32 --stages 3 --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import local_sgd as LS
from repro.core.stl_sgd import StagewiseDriver
from repro.data.synthetic import make_token_stream
from repro.engine import algorithm_names
from repro.launch.mesh import make_host_mesh
from repro.utils.logging import get_logger

log = get_logger("train")


def synthetic_batches(cfg, n_clients, batch_per_client, seq_len, seed=0,
                      non_iid=False):
    """Infinite (C, B, S) token/label batches from per-client shards."""
    shards = make_token_stream(200_000, cfg.vocab_size, n_clients, seed=seed,
                               non_iid=non_iid)
    rng = np.random.RandomState(seed)
    fe_rng = np.random.RandomState(seed + 1)
    n = shards.shape[1] - seq_len - 1
    while True:
        starts = rng.randint(0, n, size=(n_clients, batch_per_client))
        toks = np.stack([
            np.stack([shards[c, s: s + seq_len] for s in starts[c]])
            for c in range(n_clients)])
        labs = np.stack([
            np.stack([shards[c, s + 1: s + seq_len + 1] for s in starts[c]])
            for c in range(n_clients)])
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.frontend:
            batch["frontend"] = jnp.asarray(fe_rng.randn(
                n_clients, batch_per_client, cfg.n_frontend_tokens,
                cfg.frontend_dim).astype(np.float32), dtype=jnp.bfloat16)
        yield batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--algo", default="stl_sc",
                    choices=list(algorithm_names()))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eta1", type=float, default=0.05)
    ap.add_argument("--k1", type=float, default=4)
    ap.add_argument("--T1", type=int, default=32)
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--gamma-inv", type=float, default=0.0)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--reducer", default="dense",
                    help="communication reducer: dense | int8 | int<b> | topk")
    ap.add_argument("--topology", default="star",
                    choices=["star", "streaming", "hier"],
                    help="sync round shape: flat star | per-leaf streaming "
                         "| two-level hierarchical (pods of clients)")
    ap.add_argument("--pods", type=int, default=2,
                    help="n_pods for --topology hier (clients split into "
                         "contiguous pods; 1 degenerates to the flat round)")
    ap.add_argument("--inter-reducer", default="int8",
                    help="inter-pod reducer for --topology hier "
                         "(the WAN hop): dense | int8 | int<b> | topk")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-out", default=None, metavar="DIR",
                    help="write the final params as a serveable checkpoint: "
                         "meta records arch/smoke + the full stagewise "
                         "schedule, so launch/serve.py --ckpt DIR can "
                         "rebuild the config and restore without flags")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export a Perfetto-loadable Chrome trace of the "
                         "run's span timeline (plus the comm.*/train.* "
                         "counter tracks) to this path, and a .jsonl span "
                         "log next to it")
    ap.add_argument("--profile", action="store_true",
                    help="wall-time the jitted train/sync steps (block-"
                         "until-ready) against their modeled prices and "
                         "print the modeled-vs-measured skew table")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="also bracket the run in a jax.profiler trace "
                         "session writing XPlane artifacts to DIR "
                         "(implies --profile)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(algo=args.algo, eta1=args.eta1, k1=args.k1, T1=args.T1,
                       n_stages=args.stages, iid=not args.non_iid,
                       gamma_inv=args.gamma_inv, momentum=args.momentum,
                       seed=args.seed, reducer=args.reducer,
                       topology=args.topology, n_pods=args.pods,
                       inter_reducer=args.inter_reducer)
    mesh = make_host_mesh(1, 1)
    C = args.clients

    log.info("arch=%s algo=%s clients=%d", cfg.name, args.algo, C)
    state = LS.init_state(jax.random.key(args.seed), cfg, C, args.optimizer)
    train_local, sync_step, _ = LS.build_train_steps(
        cfg, mesh, client_axis="data", optimizer=args.optimizer,
        momentum=args.momentum, reducer=args.reducer,
        streaming=args.topology == "streaming")
    if args.topology == "hier":
        # the two-level round: dense intra-pod (args.reducer) + compressed
        # inter-pod — the driver prices it through engine.Hierarchical
        sync_step = LS.build_sync_step(args.reducer, hierarchical=True,
                                       n_pods=args.pods,
                                       inter_reducer=args.inter_reducer)

    uses_center = args.algo in ("stl_nc1", "stl_nc2") and args.gamma_inv > 0
    if uses_center:
        from repro.core.prox import prox_loss

        base = lambda p, c, b: LS.lm_loss(p, c, b)
        pl = prox_loss(lambda p, b: LS.lm_loss(p, cfg, b), args.gamma_inv)

        def loss_with_center(p, c, b, center):
            return pl(p, b, center)

        def train_with_center(state, batch, eta, center):
            # rebuild a step closing over the center
            tl, _, _ = LS.build_train_steps(
                cfg, mesh, client_axis="data", optimizer=args.optimizer,
                momentum=args.momentum,
                loss_fn=lambda p, c, b: pl(p, b, center))
            return tl(state, batch, eta)

        train_fn = jax.jit(lambda s, b, e, c: train_with_center(s, b, e, c))
    else:
        train_fn = jax.jit(train_local)
    sync_fn = jax.jit(sync_step)

    profile = None
    if args.profile or args.profile_dir:
        from repro.obs import ProfileSession
        from repro.serve.engine import DeviceModel

        profile = ProfileSession(logdir=args.profile_dir)
        # one train step = C clients × batch × seq tokens on the roofline
        train_price = DeviceModel().step_time_s(
            cfg, ShapeConfig("train_step", args.seq, C * args.batch,
                             "train"))
        # the sync round is priced from the driver's own topology, which
        # only exists below — resolve the price lazily per call
        sync_price = {"v": 0.0}
        train_fn = profile.wrap(train_fn, "train_step", train_price)
        # wrapping keeps the build_sync_step tags reachable through the
        # __wrapped__ chain, so the driver still prices the tagged round
        sync_fn = profile.wrap(sync_fn, "sync_step",
                               lambda *a, **k: sync_price["v"])

    driver = StagewiseDriver(tcfg, train_fn, sync_fn, uses_center=uses_center)
    if profile is not None:
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
            state["params"])
        sync_price["v"] = sum(
            h.time_s for h in driver.build_topology().hop_costs(template, C))
    batches = synthetic_batches(cfg, C, args.batch, args.seq, args.seed,
                                args.non_iid)
    tracer = None
    if args.trace:
        from repro.obs import Tracer
        from repro.utils.logging import RUN_ID
        tracer = Tracer(run_id=RUN_ID)
    t0 = time.time()
    if profile is not None:
        with profile:
            ds = driver.run(state, batches, max_iters=args.steps,
                            tracer=tracer)
    else:
        ds = driver.run(state, batches, max_iters=args.steps, tracer=tracer)
    dt = time.time() - t0
    log.info("done: %d iters, %d comm rounds, %.1fs (%.1f it/s)",
             ds.iters_total, ds.rounds_total, dt, ds.iters_total / max(dt, 1e-9))
    for r in ds.results:
        log.info("  stage %d: k=%d rounds=%d loss=%.4f", r.stage, r.k,
                 r.rounds, r.mean_loss)
    if profile is not None:
        from repro.obs import format_skew_table
        profile.emit_spans(tracer)
        print(format_skew_table(profile.skew_table()))
    if tracer is not None:
        from repro.obs import series as obs_series
        from repro.obs import write_chrome_trace, write_jsonl
        write_chrome_trace(tracer, args.trace,
                           series=obs_series.registry())
        write_jsonl(tracer, args.trace + "l")   # foo.json -> foo.jsonl
        log.info("trace_written", path=args.trace, spans=len(tracer.spans))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, ds.iters_total, ds.state["params"],
                        {"algo": args.algo, "rounds": ds.rounds_total})
        log.info("checkpoint written to %s", args.ckpt_dir)
    if args.ckpt_out:
        # serveable checkpoint: the consensus params x̄ (client-axis mean —
        # identical across clients right after a sync round), plus meta
        # carrying everything ServeEngine.from_checkpoint needs to rebuild
        # the arch and the stagewise schedule actually executed
        consensus = jax.tree.map(lambda p: p.mean(axis=0),
                                 ds.state["params"])
        meta = {
            "arch": args.arch, "smoke": bool(args.smoke),
            "algo": args.algo, "eta1": args.eta1, "k1": args.k1,
            "T1": args.T1, "n_stages": args.stages,
            "iters": ds.iters_total, "rounds": ds.rounds_total,
            "stages": [{"stage": r.stage, "k": r.k, "rounds": r.rounds,
                        "eta": r.eta, "mean_loss": float(r.mean_loss)}
                       for r in ds.results],
        }
        path = save_checkpoint(args.ckpt_out, ds.iters_total, consensus,
                               meta)
        log.info("serveable checkpoint written to %s", path)
    return ds


if __name__ == "__main__":
    main()
