from repro.models import transformer, attention, moe, ssm, rglru, layers, cnn, logreg

__all__ = ["transformer", "attention", "moe", "ssm", "rglru", "layers", "cnn", "logreg"]
