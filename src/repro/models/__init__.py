from repro.models import transformer, attention, moe, ssm, rglru, layers, cnn, logreg, mlp

__all__ = ["transformer", "attention", "moe", "ssm", "rglru", "layers", "cnn", "logreg", "mlp"]
