"""Attention variants: GQA (qk_norm / softcap / sliding window) and MLA.

Supports three execution modes from one code path:
  * full-sequence (training / prefill) with causal or sliding-window masks,
  * single-token decode against a full KV cache,
  * single-token decode against a ring-buffer (sliding-window) KV cache —
    O(window) state, what makes long_500k lowerable for attention archs.

MLA (DeepSeek-V2 / MiniCPM3) caches the compressed latent + rope key and uses
the *absorbed* formulation at decode time (scores computed in latent space),
the memory-bandwidth-optimal form on TPU.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttentionConfig
from repro.models.layers import apply_rope, dense_init, rms_norm, softcap
from repro.sharding import shard

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype):
    att = cfg.attention
    assert att is not None
    d = cfg.d_model
    if att.kind == "gqa":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "wq": dense_init(k1, d, att.n_heads * att.head_dim, dtype),
            "wk": dense_init(k2, d, att.n_kv_heads * att.head_dim, dtype),
            "wv": dense_init(k3, d, att.n_kv_heads * att.head_dim, dtype),
            "wo": dense_init(k4, att.n_heads * att.head_dim, d, dtype),
        }
        if att.qk_norm:
            p["q_norm"] = jnp.zeros((att.head_dim,), dtype)
            p["k_norm"] = jnp.zeros((att.head_dim,), dtype)
        return p
    elif att.kind == "mla":
        keys = jax.random.split(key, 8)
        qk_dim = att.qk_nope_head_dim + att.qk_rope_head_dim
        p = {
            "w_dkv": dense_init(keys[0], d, att.kv_lora_rank + att.qk_rope_head_dim, dtype),
            "kv_norm": jnp.zeros((att.kv_lora_rank,), dtype),
            "w_uk": dense_init(keys[1], att.kv_lora_rank, att.n_heads * att.qk_nope_head_dim, dtype),
            "w_uv": dense_init(keys[2], att.kv_lora_rank, att.n_heads * att.v_head_dim, dtype),
            "wo": dense_init(keys[3], att.n_heads * att.v_head_dim, d, dtype),
        }
        if att.q_lora_rank:
            p["w_dq"] = dense_init(keys[4], d, att.q_lora_rank, dtype)
            p["q_norm"] = jnp.zeros((att.q_lora_rank,), dtype)
            p["w_uq"] = dense_init(keys[5], att.q_lora_rank, att.n_heads * qk_dim, dtype)
        else:
            p["wq"] = dense_init(keys[4], d, att.n_heads * qk_dim, dtype)
        return p
    raise ValueError(att.kind)


# ---------------------------------------------------------------------------
# Mask / core attention
# ---------------------------------------------------------------------------

def _mask_bias(pos_q, pos_k, window: Optional[int]):
    """(Sq, Sk) additive bias: causal (+ sliding window). pos_* are int32 arrays."""
    ok = pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        ok &= pos_k[None, :] > pos_q[:, None] - window
    ok &= pos_k[None, :] >= 0  # ring-buffer slots not yet written carry pos -1
    return jnp.where(ok, 0.0, NEG_INF)


CHUNK_Q_THRESHOLD = 2048  # above this, full-seq attention runs q-chunked
CHUNK_Q = 1024


def attend(q, k, v, bias, cap: Optional[float], scale: float):
    """q: (B,Sq,H,hd) k,v: (B,Sk,KV,hd'), grouped-query without repeating KV.

    For long sequences the q axis is processed in CHUNK_Q blocks under
    lax.scan (flash-style online softmax is unnecessary here since each block
    still sees all of K — the point is never materialising the full (Sq,Sk)
    score tensor). The Pallas kernel (repro.kernels.flash_attention) is the
    TPU-optimal version of the same contraction.
    """
    B, Sq, H, hd = q.shape
    if Sq > CHUNK_Q_THRESHOLD and Sq % CHUNK_Q == 0:
        nq = Sq // CHUNK_Q
        qb = q.reshape(B, nq, CHUNK_Q, H, hd)
        bb = bias.reshape(nq, CHUNK_Q, bias.shape[-1])

        def body(_, inp):
            qi, bi = inp
            return None, _attend_block(qi, k, v, bi, cap, scale)

        _, out = jax.lax.scan(body, None,
                              (jnp.moveaxis(qb, 1, 0), bb))
        return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, v.shape[-1])
    return _attend_block(q, k, v, bias, cap, scale)


def _attend_block(q, k, v, bias, cap: Optional[float], scale: float):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    scores = softcap(scores, cap)
    scores = scores + bias  # bias (Sq, Sk) broadcasts
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------

def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (symmetric, per position×head)
# ---------------------------------------------------------------------------

def _quant(x):
    """x: (..., hd) → (int8 values, fp32 scales (...,))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def apply_gqa(params, att: AttentionConfig, x, pos_q, *, window, eps,
              cache=None, cache_pos=None, kv_quant=False):
    """x: (B, S, d). pos_q: (S,) absolute positions of the tokens in x.

    cache: None (full-seq) or {"k","v"} buffers (B, C, KV, hd) where C is
    max_len (full cache) or window size (ring buffer). cache_pos: scalar count
    of tokens already in the cache (== absolute position of x[:,0]).
    """
    B, S, d = x.shape
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, params["wq"]), att.n_heads, att.head_dim)
    k = _split_heads(jnp.einsum("bsd,df->bsf", x, params["wk"]), att.n_kv_heads, att.head_dim)
    v = _split_heads(jnp.einsum("bsd,df->bsf", x, params["wv"]), att.n_kv_heads, att.head_dim)
    q = shard(q, None, None, "model", None)
    k = shard(k, None, None, "model", None)
    v = shard(v, None, None, "model", None)
    if att.qk_norm:
        q = rms_norm(q, params["q_norm"], eps)
        k = rms_norm(k, params["k_norm"], eps)
    q = apply_rope(q, pos_q, att.rope_theta)
    k = apply_rope(k, pos_q, att.rope_theta)
    scale = 1.0 / math.sqrt(att.head_dim)

    if cache is None:
        bias = _mask_bias(pos_q, pos_q, window)
        out = attend(q, k, v, bias, att.logit_softcap, scale)
    elif S > 1:
        # prefill: attend over the full in-flight sequence (window-masked),
        # then store the last C positions into the (possibly ring) cache.
        bias = _mask_bias(pos_q, pos_q, window)
        out = attend(q, k, v, bias, att.logit_softcap, scale)
        if kv_quant:
            kq, ks = _quant(k)
            vq, vs = _quant(v)
            cache = {"k": _write_tail(cache["k"], kq),
                     "k_scale": _write_tail_scale(cache["k_scale"], ks),
                     "v": _write_tail(cache["v"], vq),
                     "v_scale": _write_tail_scale(cache["v_scale"], vs)}
        else:
            cache = {"k": _write_tail(cache["k"], k),
                     "v": _write_tail(cache["v"], v)}
    else:
        C = cache["k"].shape[1]
        slot = jnp.mod(cache_pos, C)
        if kv_quant:
            kq, ks = _quant(k)
            vq, vs = _quant(v)
            cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, slot, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, slot, 0)),
            }
            kr = _dequant(cache["k"], cache["k_scale"], x.dtype)
            vr = _dequant(cache["v"], cache["v_scale"], x.dtype)
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0)),
            }
            kr, vr = cache["k"], cache["v"]
        pos_k = _cache_positions(C, cache_pos)
        bias = _mask_bias(pos_q, pos_k, window)
        out = attend(q, kr, vr, bias, att.logit_softcap, scale)

    out = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, -1), params["wo"])
    return out, cache


def _write_tail(buf, x):
    """Store the last C positions of x (B,S,...) into the cache buffer (B,C,...).

    Prefill-from-zero only. Ring invariant: slot j holds position p with
    p % C == j, so for S > C the tail is rolled by S % C.
    """
    C, S = buf.shape[1], x.shape[1]
    if S >= C:
        tail = x[:, -C:].astype(buf.dtype)
        if S % C:
            tail = jnp.roll(tail, S % C, axis=1)
        return tail
    return jax.lax.dynamic_update_slice(
        buf, x.astype(buf.dtype), (0,) * buf.ndim)


def _cache_positions(C: int, cache_pos):
    """Absolute position held by each of the C cache slots after writing the
    token at ``cache_pos`` into slot ``cache_pos % C`` (ring semantics).

    Slots never written hold -1 (masked out by _mask_bias).
    """
    slots = jnp.arange(C, dtype=jnp.int32)
    cur = jnp.mod(cache_pos, C)
    base = cache_pos - cur  # start of the current ring revolution
    pos = jnp.where(slots <= cur, base + slots, base - C + slots)
    return jnp.where(pos >= 0, pos, -1)


def _write_tail_scale(buf, s):
    """Ring-write for the (B,S,KV) scale tensor (adds/strips a dummy dim)."""
    return _write_tail(buf[..., None], s[..., None])[..., 0]


def init_gqa_cache(att: AttentionConfig, batch: int, max_len: int, window,
                   dtype, kv_quant=False):
    C = min(max_len, window) if window is not None else max_len
    shape = (batch, C, att.n_kv_heads, att.head_dim)
    if kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA apply
# ---------------------------------------------------------------------------

def _mla_q(params, att: AttentionConfig, x, pos_q, eps):
    B, S, _ = x.shape
    qk_dim = att.qk_nope_head_dim + att.qk_rope_head_dim
    if att.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,df->bsf", x, params["w_dq"]), params["q_norm"], eps)
        q = jnp.einsum("bsf,fg->bsg", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,df->bsf", x, params["wq"])
    q = q.reshape(B, S, att.n_heads, qk_dim)
    q = shard(q, None, None, "model", None)
    q_nope = q[..., : att.qk_nope_head_dim]
    q_rope = apply_rope(q[..., att.qk_nope_head_dim:], pos_q, att.rope_theta)
    return q_nope, q_rope


def apply_mla(params, att: AttentionConfig, x, pos_q, *, window, eps,
              cache=None, cache_pos=None):
    """MLA attention. cache: {"ckv": (B,C,r), "k_rope": (B,C,rd)} or None."""
    B, S, d = x.shape
    H = att.n_heads
    nope, rd, vd, r = att.qk_nope_head_dim, att.qk_rope_head_dim, att.v_head_dim, att.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rd)

    q_nope, q_rope = _mla_q(params, att, x, pos_q, eps)

    dkv = jnp.einsum("bsd,df->bsf", x, params["w_dkv"])
    ckv = rms_norm(dkv[..., :r], params["kv_norm"], eps)          # (B,S,r)
    k_rope = apply_rope(dkv[..., r:][:, :, None, :], pos_q, att.rope_theta)[:, :, 0, :]

    if cache is None or S > 1:
        k_nope = jnp.einsum("bsr,rf->bsf", ckv, params["w_uk"]).reshape(B, S, H, nope)
        v = jnp.einsum("bsr,rf->bsf", ckv, params["w_uv"]).reshape(B, S, H, vd)
        k_nope = shard(k_nope, None, None, "model", None)
        v = shard(v, None, None, "model", None)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))], axis=-1)
        bias = _mask_bias(pos_q, pos_q, window)
        out = attend(q, k, v, bias, att.logit_softcap, scale)
        new_cache = None
        if cache is not None:  # prefill: store latent tail
            new_cache = {"ckv": _write_tail(cache["ckv"], ckv),
                         "k_rope": _write_tail(cache["k_rope"], k_rope)}
    else:
        # absorbed decode: scores & values in latent space, cache stays (r+rd).
        C = cache["ckv"].shape[1]
        slot = jnp.mod(cache_pos, C)
        ckv_c = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, slot, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, slot, 0))
        new_cache = {"ckv": ckv_c, "k_rope": kr_c}
        pos_k = _cache_positions(C, cache_pos)
        w_uk = params["w_uk"].reshape(r, H, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
        scores = jnp.einsum("bshr,bcr->bhsc", q_lat, ckv_c.astype(jnp.float32))
        scores += jnp.einsum("bshr,bcr->bhsc", q_rope.astype(jnp.float32), kr_c.astype(jnp.float32))
        scores *= scale
        scores = softcap(scores, att.logit_softcap)
        bias = _mask_bias(pos_q, pos_k, window)
        w = jax.nn.softmax(scores + bias[None, None], axis=-1)
        o_lat = jnp.einsum("bhsc,bcr->bshr", w, ckv_c.astype(jnp.float32))
        w_uv = params["w_uv"].reshape(r, H, vd)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)

    out = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, -1), params["wo"])
    return out, new_cache


def init_mla_cache(att: AttentionConfig, batch: int, max_len: int, window, dtype):
    C = min(max_len, window) if window is not None else max_len
    return {
        "ckv": jnp.zeros((batch, C, att.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, C, att.qk_rope_head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# Unified entry
# ---------------------------------------------------------------------------

def apply_attention(params, cfg: ArchConfig, x, pos_q, *, is_local: bool,
                    cache=None, cache_pos=None):
    att = cfg.attention
    window = att.window if is_local else None
    if att.kind == "mla":
        # MLA's latent cache is already ~8x smaller than GQA KV; int8 applies
        # to the latent the same way (not enabled by default).
        return apply_mla(params, att, x, pos_q, window=window, eps=cfg.norm_eps,
                         cache=cache, cache_pos=cache_pos)
    return apply_gqa(params, att, x, pos_q, window=window, eps=cfg.norm_eps,
                     cache=cache, cache_pos=cache_pos, kv_quant=cfg.kv_quant)


def init_attention_cache(cfg: ArchConfig, is_local: bool, batch: int, max_len: int, dtype):
    att = cfg.attention
    window = att.window if is_local else None
    if att.kind == "mla":
        return init_mla_cache(att, batch, max_len, window, dtype)
    return init_gqa_cache(att, batch, max_len, window, dtype,
                          kv_quant=cfg.kv_quant)
