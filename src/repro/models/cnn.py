"""ResNet18 / VGG16 — the paper's §5.2 non-convex experiments (CIFAR-10).

Pure-JAX conv nets (functional, dict params). Group-norm free: we use
BatchNorm-less "NF-style" scaled residuals for simplicity and determinism
across clients (BatchNorm's cross-batch statistics interact badly with the
Local SGD client partition; the paper does not depend on BN specifics).
A ``width`` knob lets the CPU benchmarks run reduced-width variants of the
same topology.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# ResNet18
# ---------------------------------------------------------------------------

_RESNET18_STAGES = ((2, 1), (2, 2), (2, 2), (2, 2))  # (blocks, first-stride) per stage


def init_resnet18(rng, n_classes: int = 10, width: int = 64):
    keys = iter(jax.random.split(rng, 64))
    p = {"stem": _conv_init(next(keys), 3, 3, 3, width)}
    cin = width
    stages = []
    for si, (blocks, stride) in enumerate(_RESNET18_STAGES):
        cout = width * (2 ** si)
        blist = []
        for b in range(blocks):
            s = stride if b == 0 else 1
            blk = {
                "conv1": _conv_init(next(keys), 3, 3, cin, cout),
                "conv2": _conv_init(next(keys), 3, 3, cout, cout),
                "scale1": jnp.ones((cout,)), "scale2": jnp.zeros((cout,)),
            }
            if s != 1 or cin != cout:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
            blk["stride"] = s  # static int (not a leaf — removed below)
            blist.append(blk)
            cin = cout
        stages.append(blist)
    # strip static ints out of the pytree; keep strides separately
    strides = [[blk.pop("stride") for blk in st] for st in stages]
    p["stages"] = stages
    p["head_w"] = jax.random.normal(next(keys), (cin, n_classes), jnp.float32) * 0.01
    p["head_b"] = jnp.zeros((n_classes,))
    return p, strides


def apply_resnet18(params, strides, x):
    """x: (B, 32, 32, 3) → logits (B, n_classes)."""
    h = _conv(x, params["stem"])
    for st, st_strides in zip(params["stages"], strides):
        for blk, s in zip(st, st_strides):
            inp = h
            h = jax.nn.relu(_conv(inp, blk["conv1"], s) * blk["scale1"])
            h = _conv(h, blk["conv2"]) * (1.0 + blk["scale2"])
            sc = _conv(inp, blk["proj"], s) if "proj" in blk else inp
            h = jax.nn.relu(h + sc)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head_w"] + params["head_b"]


# ---------------------------------------------------------------------------
# VGG16
# ---------------------------------------------------------------------------

_VGG16_PLAN = ((2, 1), (2, 2), (3, 4), (3, 8), (3, 8))  # (convs, width-mult) per stage


def init_vgg16(rng, n_classes: int = 10, width: int = 64):
    keys = iter(jax.random.split(rng, 64))
    p = {"stages": []}
    cin = 3
    for convs, mult in _VGG16_PLAN:
        cout = width * mult
        st = []
        for _ in range(convs):
            st.append({"conv": _conv_init(next(keys), 3, 3, cin, cout),
                       "scale": jnp.ones((cout,))})
            cin = cout
        p["stages"].append(st)
    p["fc1"] = jax.random.normal(next(keys), (cin, 4 * width), jnp.float32) * 0.02
    p["fc2"] = jax.random.normal(next(keys), (4 * width, n_classes), jnp.float32) * 0.02
    p["b1"] = jnp.zeros((4 * width,))
    p["b2"] = jnp.zeros((n_classes,))
    return p


def apply_vgg16(params, x):
    h = x
    for st in params["stages"]:
        for blk in st:
            h = jax.nn.relu(_conv(h, blk["conv"]) * blk["scale"])
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = jnp.mean(h, axis=(1, 2))
    h = jax.nn.relu(h @ params["fc1"] + params["b1"])
    return h @ params["fc2"] + params["b2"]


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
