"""Shared neural-net building blocks (pure functions, dict params).

All model code is single-replica: the Local-SGD client axis is added by the
trainer with ``jax.vmap(..., spmd_axis_name=...)`` so the same functions serve
training (N client replicas) and inference (no client axis).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x, cap: Optional[float]):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def apply_mlp(params, x):
    from repro.sharding import shard

    h = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = shard(jax.nn.silu(h) * u, *((None,) * (x.ndim - 1)), "model")
    return jnp.einsum("...f,fd->...d", h, params["w_down"])
