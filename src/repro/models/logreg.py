"""L2-regularized logistic regression — the paper's §5.1 convex problem.

    min_θ (1/n) Σ log(1 + exp(-y_i x_iᵀθ)) + (λ/2)||θ||²   (Eq. 7)

λ > 0 makes this λ-strongly convex; the paper sets λ = 1/n.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_params(rng, n_features: int):
    return {"theta": jnp.zeros((n_features,), jnp.float32)}


def loss_fn(params, batch, lam: float):
    """batch: {"x": (B, d), "y": (B,) in {-1, +1}}."""
    margin = batch["y"] * (batch["x"] @ params["theta"])
    # log(1 + exp(-m)) = softplus(-m), numerically stable
    data_loss = jnp.mean(jax.nn.softplus(-margin))
    reg = 0.5 * lam * jnp.sum(jnp.square(params["theta"]))
    return data_loss + reg


def full_objective(params, x, y, lam: float):
    return loss_fn(params, {"x": x, "y": y}, lam)


def accuracy(params, x, y):
    pred = jnp.sign(x @ params["theta"])
    return jnp.mean(pred == y)
