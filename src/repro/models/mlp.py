"""Small multi-layer perceptron — the multi-leaf streaming-reduce testbed.

Same binary-classification problem shape as ``models.logreg`` (batch =
``{"x": (B, d), "y": (B,) in {-1, +1}}``, L2-regularized logistic loss) but
with a parameter *tree* of ≥ 4 leaves: ``depth`` equal-width tanh hidden
layers plus a linear head. Streaming per-leaf uploads only help when the
model has several comparably-sized leaves whose last local step completes
at different times (reverse-layer order under backprop) — logreg's single
``theta`` leaf can never overlap anything, which is exactly what
``benchmarks/table5_straggler.py``'s {blocking, streaming} axis needs a
contrast against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_params(rng, n_features: int, width: int = 96, depth: int = 3):
    """He-initialized MLP params: ``depth`` hidden {w, b} pairs + a head.

    2·(depth + 1) leaves; with the default width the three hidden weight
    matrices are equal-sized, which maximises the streaming overlap window
    (each leaf's upload hides behind the next leaf's backward compute).
    """
    rng = jax.random.key(0) if rng is None else rng
    keys = jax.random.split(rng, depth + 1)
    layers = []
    d_in = n_features
    for i in range(depth):
        w = jax.random.normal(keys[i], (d_in, width), jnp.float32) \
            * jnp.sqrt(2.0 / d_in)
        layers.append({"w": w, "b": jnp.zeros((width,), jnp.float32)})
        d_in = width
    head = {"w": jax.random.normal(keys[depth], (d_in, 1), jnp.float32)
            * jnp.sqrt(2.0 / d_in),
            "b": jnp.zeros((1,), jnp.float32)}
    return {"layers": layers, "out": head}


def forward(params, x):
    """Per-example logit: tanh MLP over (B, d) features -> (B,)."""
    h = x
    for lyr in params["layers"]:
        h = jnp.tanh(h @ lyr["w"] + lyr["b"])
    return (h @ params["out"]["w"] + params["out"]["b"])[:, 0]


def loss_fn(params, batch, lam: float):
    """Mean logistic loss + (λ/2)·||params||² (all leaves)."""
    margin = batch["y"] * forward(params, batch["x"])
    data_loss = jnp.mean(jax.nn.softplus(-margin))
    reg = 0.5 * lam * sum(jnp.sum(jnp.square(l))
                          for l in jax.tree.leaves(params))
    return data_loss + reg


def full_objective(params, x, y, lam: float):
    return loss_fn(params, {"x": x, "y": y}, lam)


def accuracy(params, x, y):
    pred = jnp.sign(forward(params, x))
    return jnp.mean(pred == y)
