"""Mixture-of-Experts layer: top-k router + shared experts.

Dispatch is capacity-based (GShard-style) but without the (T,E,C) one-hot
einsum: token→slot assignment is computed with a cumsum rank and realised with
scatter/gather, so compiled FLOPs stay proportional to *active* expert compute
(the batched (E,C,d)×(E,d,f) matmuls). Expert weights live on the `model` mesh
axis (expert parallelism); the scatter into the E-sharded buffer and the
gather back are where XLA inserts the all-to-all-like collectives the paper's
roofline tracks for MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init
from repro.sharding import shard


def init_moe(key, cfg: ArchConfig, dtype):
    moe = cfg.moe
    assert moe is not None
    d, de, E = cfg.d_model, moe.d_expert, moe.n_experts
    keys = jax.random.split(key, 6)
    p = {
        "w_router": dense_init(keys[0], d, E, jnp.float32),
        "we_gate": _expert_init(keys[1], E, d, de, dtype),
        "we_up": _expert_init(keys[2], E, d, de, dtype),
        "we_down": _expert_init(keys[3], E, de, d, dtype),
    }
    if moe.n_shared > 0:
        # shared experts = one dense SwiGLU of width n_shared * d_expert
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(keys[4], d, moe.n_shared * de, dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    scale = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (E, d_in, d_out), jnp.float32) * scale).astype(dtype)


def _moe_pool(params, moe: MoEConfig, xt):
    """Dispatch+compute+combine for one token pool. xt: (T, d) → (T, d), aux."""
    T, d = xt.shape
    E, k = moe.n_experts, moe.top_k

    # --- router (fp32 for stability) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T,k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balance aux loss (Switch-style) ---
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = moe.aux_coef * E * jnp.sum(me * ce)

    # --- capacity assignment ---
    C = max(1, min(T, int(T * k / E * moe.capacity_factor)))
    flat_e = idx.reshape(-1)                       # (T*k,) expert id
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    rank = jnp.cumsum(onehot, axis=0) - 1          # running count per expert
    rank = jnp.sum(rank * onehot, axis=-1)         # (T*k,) position within expert
    keep = rank < C
    slot = flat_e * C + jnp.minimum(rank, C - 1)   # (T*k,) in [0, E*C)
    slot = jnp.where(keep, slot, E * C)            # overflow → dropped row

    # --- dispatch: scatter tokens into (E*C, d) buffers ---
    token_of = jnp.repeat(jnp.arange(T), k)        # (T*k,)
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(xt[token_of])
    buf = buf[: E * C].reshape(E, C, d)

    # --- expert compute: batched SwiGLU over (E, C, d) ---
    h = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["we_down"])

    # --- combine: gather each assignment's slot output, weight, scatter-add ---
    out_flat = out.reshape(E * C, d)
    picked = jnp.where(keep[:, None], out_flat[jnp.minimum(slot, E * C - 1)], 0.0)
    y = jnp.zeros((T, d), xt.dtype).at[token_of].add(
        picked * gate_vals.reshape(-1)[:, None].astype(xt.dtype)
    )
    return y, aux


def apply_moe(params, cfg: ArchConfig, x):
    """x: (B, S, d) → (B, S, d), aux_loss (scalar, load-balance).

    Dispatch is *grouped per batch row* (EXPERIMENTS.md §Perf iteration b1):
    each data-shard's tokens form their own capacity pool, so the scatter into
    the expert buffers is local to the shard and the expert matmuls are batch
    dims over (group × expert) — the only cross-device traffic left is the
    E-sharded combine (≈ one y-sized all-reduce over `model`). The flat
    global-pool variant (moe.grouped=False) all-gathers the (E, C_global, d)
    buffers instead — ~100× more collective bytes at prefill_32k scale.
    """
    moe = cfg.moe
    B, S, d = x.shape
    if not getattr(moe, "grouped", True):
        y, aux = _moe_pool(params, moe, x.reshape(B * S, d))
        y = y.reshape(B, S, d)
    else:
        y, auxes = jax.vmap(lambda xt: _moe_pool(params, moe, xt))(
            x.reshape(B, S, d))
        aux = jnp.mean(auxes)
    y = shard(y, None, None, None)

    if "shared" in params:
        from repro.models.layers import apply_mlp

        y = y + apply_mlp(params["shared"], x)

    return y, aux
