"""RG-LRU recurrent block (RecurrentGemma / Griffin). [arXiv:2402.19427]

TPU adaptation: the token recurrence h_t = a_t ⊙ h_{t-1} + √(1-a_t²) ⊙ (i_t ⊙ x_t)
is a diagonal linear recurrence, evaluated with ``jax.lax.associative_scan``
(log-depth, VPU-friendly) instead of a sequential CUDA scan. Decode keeps the
O(d) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init
from repro.models.ssm import _causal_conv
from repro.sharding import shard

_C = 8.0  # Griffin's recurrence-gate temperature


def init_rglru(key, cfg: ArchConfig, dtype):
    lru = cfg.rglru.lru_width or cfg.d_model
    d = cfg.d_model
    keys = jax.random.split(key, 6)
    return {
        "w_x": dense_init(keys[0], d, lru, dtype),
        "w_gate_lru": dense_init(keys[1], d, lru, dtype),
        "conv_lru": (jax.random.normal(keys[2], (cfg.rglru.d_conv, lru), jnp.float32) * 0.1).astype(dtype),
        "w_a": dense_init(keys[3], lru, lru, dtype),
        "w_i": dense_init(keys[4], lru, lru, dtype),
        # a = sigmoid(a_param); init so a ≈ 0.9..0.999 (Griffin: Λ init)
        "a_param": jnp.full((lru,), 4.0, jnp.float32),
        "w_out_lru": dense_init(keys[5], lru, d, dtype),
    }


def _rg_lru_scan(xb, r, i, a_param, initial_state=None):
    """xb, r, i: (B,S,lru) fp32. Returns h (B,S,lru), final state (B,lru)."""
    log_a = -_C * jax.nn.softplus(a_param)[None, None, :] * r  # log a_t  (negative)
    a = jnp.exp(log_a)
    gated = i * xb
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if initial_state is not None:
        b = b.at[:, 0].add(a[:, 0] * initial_state)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def apply_rglru(params, cfg: ArchConfig, x, cache=None):
    """x: (B,S,d). cache: None or {"conv": (B,K-1,lru), "state": (B,lru)}."""
    B, S, d = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_gate_lru"]))
    xb = jnp.einsum("bsd,df->bsf", x, params["w_x"])
    xb = shard(xb, None, None, "model")
    conv_carry = None if cache is None else cache["conv"]
    xb, new_conv = _causal_conv(xb, params["conv_lru"], conv_carry)

    r = jax.nn.sigmoid(jnp.einsum("bsf,fg->bsg", xb, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsf,fg->bsg", xb, params["w_i"]).astype(jnp.float32))
    xb32 = xb.astype(jnp.float32)

    if cache is None or S > 1:
        init_state = None if cache is None else cache["state"].astype(jnp.float32)
        h, final = _rg_lru_scan(xb32, r, i, params["a_param"], init_state)
    else:
        st = cache["state"].astype(jnp.float32)
        log_a = -_C * jax.nn.softplus(params["a_param"])[None, :] * r[:, 0]
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i[:, 0] * xb32[:, 0])
        final = a * st + b
        h = final[:, None, :]

    out = jnp.einsum("bsf,fd->bsd", (h.astype(x.dtype) * gate), params["w_out_lru"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": final.astype(cache["state"].dtype)}
    return out, new_cache


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    lru = cfg.rglru.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, lru), dtype),
        "state": jnp.zeros((batch, lru), jnp.float32),
    }
