"""Mamba2 block — SSD (state-space duality), chunked matmul form. [arXiv:2405.21060]

TPU adaptation (see DESIGN.md §2/§6): the SSD algorithm is evaluated in its
*dual* chunked-matmul form — intra-chunk terms are attention-like (Q,Q) and
(N,P) matmuls that map directly onto the MXU, and the inter-chunk recurrence
is a short ``lax.scan`` over S/chunk states. This replaces the paper's
warp-level CUDA scan with a layout the TPU memory hierarchy actually likes.

Full-sequence path: ``apply_mamba2(...)``. Decode path keeps O(1) state:
conv ring (d_conv-1 inputs) + SSM state (H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.layers import dense_init, rms_norm
from repro.sharding import shard


def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_ch = d_inner + 2 * ssm.n_groups * ssm.d_state
    d_in_proj = 2 * d_inner + 2 * ssm.n_groups * ssm.d_state + n_heads
    return d_inner, n_heads, conv_ch, d_in_proj


def init_mamba2(key, cfg: ArchConfig, dtype):
    ssm = cfg.ssm
    d_inner, n_heads, conv_ch, d_in_proj = _dims(cfg)
    keys = jax.random.split(key, 4)
    return {
        "w_in": dense_init(keys[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(keys[1], (ssm.d_conv, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "ssm_norm": jnp.zeros((d_inner,), dtype),
        "w_out_ssm": dense_init(keys[2], d_inner, cfg.d_model, dtype),
    }


def _split_in_proj(cfg: ArchConfig, zxbcdt):
    ssm = cfg.ssm
    d_inner, n_heads, _, _ = _dims(cfg)
    gN = ssm.n_groups * ssm.d_state
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gN, 2 * d_inner + 2 * gN], axis=-1
    )
    return z, xs, Bc, Cc, dt


def _causal_conv(x, w, carry=None):
    """Depthwise causal conv. x: (B,S,ch), w: (K,ch). carry: (B,K-1,ch) or None."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, ch)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_carry = xp[:, -(K - 1):, :]
    return jax.nn.silu(out), new_carry


def _segsum_exp(dA):
    """dA: (..., Q). Return exp(segsum) lower-tri matrix (..., Q, Q):
    L[i,j] = exp(sum_{j<k<=i} dA_k) for i>=j else 0."""
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    Q = dA.shape[-1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_chunked(xs, dt, A, Bc, Cc, chunk: int, initial_state=None):
    """SSD in chunked dual form.

    xs: (B,S,H,P)  dt: (B,S,H)  A: (H,)  Bc,Cc: (B,S,G,N)
    Returns y (B,S,H,P), final_state (B,H,P,N). All math fp32.
    """
    Bsz, S, H, P = xs.shape
    G, N = Bc.shape[2], Bc.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xs = xs.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dt = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bc = Bc.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Cc = Cc.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)

    dA = dt * A[None, None, None, :]          # (B,nc,Q,H)
    dAh = jnp.moveaxis(dA, -1, 2)             # (B,nc,H,Q)
    L = _segsum_exp(dAh)                      # (B,nc,H,Q,Q)
    xdt = xs * dt[..., None]                  # dt-weighted inputs

    # intra-chunk (diagonal) term: "attention" C_i · B_j with decay L
    CB = jnp.einsum("bnqgi,bnsgi->bngqs", Cc, Bc)      # (B,nc,G,Q,Q)
    CB = jnp.repeat(CB, rep, axis=2)                   # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bnhqs,bnshp->bnqhp", CB * L, xdt)

    # per-chunk final states: sum_j decay_to_end_j * B_j x_j
    seg_end = jnp.exp(jnp.cumsum(dAh, axis=-1)[..., -1:] - jnp.cumsum(dAh, axis=-1))  # (B,nc,H,Q)
    states = jnp.einsum(
        "bnshp,bnsgi,bnhs->bnhpi", xdt, Bc, seg_end
    )  # (B,nc,H,P,N) for G=1; general G via repeat
    if G > 1:
        # recompute honouring groups
        Brep = jnp.repeat(Bc, rep, axis=3) if False else None  # G>1 handled below
        raise NotImplementedError("n_groups > 1 not needed by assigned archs")

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dAh, axis=-1))  # (B,nc,H)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)

    # off-diagonal contribution: C_i · (decay_from_start_i * state_in)
    seg_start = jnp.exp(jnp.cumsum(dAh, axis=-1))  # decay from chunk start to i (inclusive)
    y_off = jnp.einsum("bnqgi,bnhpi,bnhq->bnqhp", Cc, prev_states, seg_start)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final_state


def apply_mamba2(params, cfg: ArchConfig, x, cache=None):
    """x: (B,S,d). cache: None or {"conv": (B,K-1,ch), "state": (B,H,P,N)}."""
    ssm = cfg.ssm
    d_inner, n_heads, conv_ch, _ = _dims(cfg)
    B_, S, _ = x.shape
    zxbcdt = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    zxbcdt = shard(zxbcdt, None, None, "model")
    z, xs, Bc, Cc, dt = _split_in_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_carry = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], conv_carry)
    xs = conv_out[..., :d_inner]
    Bc = conv_out[..., d_inner : d_inner + ssm.n_groups * ssm.d_state]
    Cc = conv_out[..., d_inner + ssm.n_groups * ssm.d_state :]

    xs = xs.reshape(B_, S, n_heads, ssm.head_dim)
    Bc = Bc.reshape(B_, S, ssm.n_groups, ssm.d_state)
    Cc = Cc.reshape(B_, S, ssm.n_groups, ssm.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if cache is None or S > 1:
        init_state = None if cache is None else cache["state"]
        chunk = min(ssm.chunk_size, S)
        y, final_state = ssd_chunked(xs, dt, A, Bc, Cc, chunk, init_state)
    else:
        # single-token recurrent decode: state' = exp(dt·A)·state + dt·x Bᵀ
        st = cache["state"].astype(jnp.float32)  # (B,H,P,N)
        dA1 = jnp.exp(dt[:, 0] * A[None, :])  # (B,H)
        xb = jnp.einsum(
            "bhp,bgn->bhpn", (xs[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
            Bc[:, 0].astype(jnp.float32),
        )
        final_state = st * dA1[..., None, None] + xb
        y = jnp.einsum("bhpn,bgn->bhp", final_state, Cc[:, 0].astype(jnp.float32))[:, None]

    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, params["w_out_ssm"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": final_state.astype(cache["state"].dtype)}
    return out, new_cache


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype):
    ssm = cfg.ssm
    d_inner, n_heads, conv_ch, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state), jnp.float32),
    }
