"""Decoder LM assembled from an ArchConfig.

Layer stack = [head (unrolled, e.g. deepseek's dense first layers)]
            + [scan over groups of len(block_pattern) sub-layers]
            + [tail (unrolled remainder)].

Scan-over-groups keeps HLO size O(pattern) instead of O(n_layers) — essential
for compiling 60-layer models 80× in the dry-run matrix on one CPU core.

Public API:
  init_params(rng, cfg)                  -> params pytree
  forward(params, cfg, tokens, frontend) -> (logits, aux)    # train / scoring
  init_cache(cfg, batch, max_len, dtype) -> cache pytree
  prefill(params, cfg, tokens, cache, frontend) -> (logits, cache)
  decode_step(params, cfg, tokens, cache)       -> (logits, cache)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.layers import apply_mlp, dense_init, embed_init, init_mlp, rms_norm, softcap
from repro.sharding import shard


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------

VOCAB_PAD = 256  # embedding rows padded so logits always shard on `model`


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


def _plan(cfg: ArchConfig):
    """Split layers into (head_kinds, n_groups, pattern, tail_kinds)."""
    kinds = cfg.layer_kinds()
    n_head = cfg.moe.n_dense_layers if cfg.moe else 0
    body = kinds[n_head:]
    p = len(cfg.block_pattern)
    n_groups = len(body) // p
    tail = body[n_groups * p :]
    return kinds[:n_head], n_groups, cfg.block_pattern, tail


def _layer_uses_moe(cfg: ArchConfig, in_head: bool) -> bool:
    return cfg.moe is not None and not in_head


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg: ArchConfig, kind: str, in_head: bool, dtype):
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), dtype)}
    if kind in ("G", "L"):
        p["attn"] = A.init_attention(keys[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        if _layer_uses_moe(cfg, in_head):
            p["moe"] = MOE.init_moe(keys[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(keys[1], d, cfg.d_ff, dtype)
    elif kind == "M":
        p["mamba"] = SSM.init_mamba2(keys[0], cfg, dtype)
    elif kind == "R":
        p["lru"] = RG.init_rglru(keys[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp(keys[1], d, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


def init_params(rng, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    head_kinds, n_groups, pattern, tail_kinds = _plan(cfg)
    keys = jax.random.split(rng, 8)

    vp = padded_vocab(cfg)
    params = {"embed": embed_init(keys[0], vp, cfg.d_model, dtype),
              "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, vp, dtype)
    if cfg.frontend:
        params["proj_frontend"] = dense_init(keys[2], cfg.frontend_dim, cfg.d_model, dtype)

    params["head"] = [
        _init_sublayer(k, cfg, kind, True, dtype)
        for k, kind in zip(jax.random.split(keys[3], max(1, len(head_kinds))), head_kinds)
    ][: len(head_kinds)]

    if n_groups > 0:
        def one_group(k):
            ks = jax.random.split(k, len(pattern))
            return {f"sub{i}": _init_sublayer(ks[i], cfg, kind, False, dtype)
                    for i, kind in enumerate(pattern)}

        group_keys = jax.random.split(keys[4], n_groups)
        groups = [one_group(k) for k in group_keys]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *groups)
    else:
        params["blocks"] = {}

    params["tail"] = [
        _init_sublayer(k, cfg, kind, False, dtype)
        for k, kind in zip(jax.random.split(keys[5], max(1, len(tail_kinds))), tail_kinds)
    ][: len(tail_kinds)]
    return params


def init_params_shape(cfg: ArchConfig):
    """Shapes-only init (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Sub-layer apply (shared by forward / prefill / decode)
# ---------------------------------------------------------------------------

def _apply_sublayer(p, cfg: ArchConfig, kind: str, in_head: bool, x, pos_q,
                    cache=None, cache_pos=None):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.seq_parallel and cache is None:
        # residual stream sequence-sharded over `model` between blocks:
        # the constraint below materialises as reduce-scatter on the way out
        # of the previous block and all-gather before this block's matmuls.
        x = shard(x, None, "model", None)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("G", "L"):
        att_out, new_c = A.apply_attention(
            p["attn"], cfg, h, pos_q, is_local=(kind == "L"),
            cache=cache, cache_pos=cache_pos)
        x = x + att_out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            m, aux = MOE.apply_moe(p["moe"], cfg, h2)
        else:
            m = apply_mlp(p["mlp"], h2)
        x = x + m
    elif kind == "M":
        out, new_c = SSM.apply_mamba2(p["mamba"], cfg, h, cache=cache)
        x = x + out
    elif kind == "R":
        out, new_c = RG.apply_rglru(p["lru"], cfg, h, cache=cache)
        x = x + out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h2)
    else:
        raise ValueError(kind)
    return x, aux, new_c


def _run_stack(params, cfg: ArchConfig, x, pos_q, caches=None, cache_pos=None):
    """Apply head + scanned groups + tail. caches mirrors params structure."""
    head_kinds, n_groups, pattern, tail_kinds = _plan(cfg)
    decoding = caches is not None
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"head": [], "blocks": None, "tail": []} if decoding else None

    for i, kind in enumerate(head_kinds):
        c = caches["head"][i] if decoding else None
        layer_fn = _apply_sublayer if decoding else jax.checkpoint(
            _apply_sublayer, static_argnums=(1, 2, 3))
        x, aux, nc = layer_fn(params["head"][i], cfg, kind, True, x, pos_q, c, cache_pos)
        aux_total += aux
        if decoding:
            new_caches["head"].append(nc)

    if n_groups > 0:
        if decoding:
            def body(carry, xs):
                x, aux_acc = carry
                gp, gc = xs
                ncs = {}
                for i, kind in enumerate(pattern):
                    x, aux, nc = _apply_sublayer(
                        gp[f"sub{i}"], cfg, kind, False, x, pos_q, gc[f"sub{i}"], cache_pos)
                    aux_acc += aux
                    ncs[f"sub{i}"] = nc
                return (x, aux_acc), ncs

            (x, aux_total), scanned = jax.lax.scan(
                body, (x, aux_total), (params["blocks"], caches["blocks"]))
            new_caches["blocks"] = scanned
        else:
            @jax.checkpoint  # remat: recompute block activations in backward
            def body(carry, gp):
                x, aux_acc = carry
                for i, kind in enumerate(pattern):
                    x, aux, _ = _apply_sublayer(gp[f"sub{i}"], cfg, kind, False, x, pos_q)
                    aux_acc += aux
                return (x, aux_acc), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["blocks"])
    elif decoding:
        new_caches["blocks"] = {}

    for i, kind in enumerate(tail_kinds):
        c = caches["tail"][i] if decoding else None
        layer_fn = _apply_sublayer if decoding else jax.checkpoint(
            _apply_sublayer, static_argnums=(1, 2, 3))
        x, aux, nc = layer_fn(params["tail"][i], cfg, kind, False, x, pos_q, c, cache_pos)
        aux_total += aux
        if decoding:
            new_caches["tail"].append(nc)

    return x, aux_total, new_caches


def _logits(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = softcap(logits, cfg.final_softcap)
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:  # mask pad columns out of softmax/argmax
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return shard(logits, None, None, "model")


def _embed_tokens(params, cfg: ArchConfig, tokens, frontend_embeds):
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    if cfg.frontend is not None and frontend_embeds is not None:
        fe = jnp.einsum("bnf,fd->bnd", frontend_embeds.astype(x.dtype), params["proj_frontend"])
        x = jnp.concatenate([fe, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens, frontend_embeds=None):
    """Full-sequence scoring. tokens: (B, S) int32. Returns (logits, aux)."""
    x = _embed_tokens(params, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    pos_q = jnp.arange(S, dtype=jnp.int32)
    x, aux, _ = _run_stack(params, cfg, x, pos_q)
    return _logits(params, cfg, x), aux


def _init_layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("G", "L"):
        return A.init_attention_cache(cfg, kind == "L", batch, max_len, dtype)
    if kind == "M":
        return SSM.init_mamba2_cache(cfg, batch, dtype)
    if kind == "R":
        return RG.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(dtype or cfg.dtype)
    head_kinds, n_groups, pattern, tail_kinds = _plan(cfg)
    cache = {
        "pos": jnp.zeros((), jnp.int32),
        "head": [_init_layer_cache(cfg, k, batch, max_len, dtype) for k in head_kinds],
        "tail": [_init_layer_cache(cfg, k, batch, max_len, dtype) for k in tail_kinds],
    }
    if n_groups > 0:
        one = {f"sub{i}": _init_layer_cache(cfg, k, batch, max_len, dtype)
               for i, k in enumerate(pattern)}
        cache["blocks"] = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n_groups,) + v.shape), one)
    else:
        cache["blocks"] = {}
    return cache


def _with_cache(params, cfg, tokens, cache, frontend_embeds=None):
    x = _embed_tokens(params, cfg, tokens, frontend_embeds)
    S = x.shape[1]
    cache_pos = cache["pos"]
    pos_q = cache_pos + jnp.arange(S, dtype=jnp.int32)
    layer_caches = {k: cache[k] for k in ("head", "blocks", "tail")}
    x, _, new_caches = _run_stack(params, cfg, x, pos_q, layer_caches, cache_pos)
    new_caches["pos"] = cache_pos + S
    return _logits(params, cfg, x), new_caches


def prefill(params, cfg: ArchConfig, tokens, cache, frontend_embeds=None):
    return _with_cache(params, cfg, tokens, cache, frontend_embeds)


def decode_step(params, cfg: ArchConfig, tokens, cache):
    """tokens: (B, 1). One decode step against the cache."""
    return _with_cache(params, cfg, tokens, cache)
