"""repro.obs — observability for the engine stack.

The cross-cutting layer that turns every run into trace data:

  * ``obs.trace`` — nested spans (``stage`` > ``round`` > ``reduce[hop]``
    > ``reduce_leaf[leaf]``, plus ``local_steps`` / ``broadcast`` /
    ``merge``) on three clock domains: measured wall time, the event
    runtime's virtual clock, and the engine ledger's modeled α–β
    timeline. Zero overhead when disabled (``NULL_TRACER`` is falsy).
  * ``obs.metrics`` — process-local counters/gauges/histograms all three
    backends and the comm reducers report into; snapshotted into
    ``EngineReport.metrics``.
  * ``obs.export`` — JSONL span logs and Chrome-trace/Perfetto JSON
    (one track per client/pod/leaf, spans colored by phase) that
    https://ui.perfetto.dev opens directly.
  * ``obs.diff`` — schema-validated BENCH_*.json loading and numeric
    regression diffing (``tools/bench_diff.py``, CI).

See docs/observability.md for the span taxonomy, metric/unit tables and
the Perfetto walkthrough.
"""
from repro.obs.diff import (
    BenchSchemaError,
    Delta,
    DIFF_KEYS,
    DirDiff,
    diff_benches,
    diff_dirs,
    load_bench,
    row_key,
    validate_bench,
)
from repro.obs.export import (
    span_record,
    to_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    reset,
)
from repro.obs.trace import (
    CAT_COMM,
    CAT_COMPUTE,
    CAT_CONTROL,
    CAT_MERGE,
    MODELED,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    VIRTUAL,
    WALL,
)

__all__ = [
    "BenchSchemaError", "Delta", "DIFF_KEYS", "DirDiff", "diff_benches",
    "diff_dirs", "load_bench", "row_key", "validate_bench",
    "span_record", "to_chrome_trace", "write_chrome_trace", "write_jsonl",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry", "reset",
    "CAT_COMM", "CAT_COMPUTE", "CAT_CONTROL", "CAT_MERGE", "MODELED",
    "NULL_TRACER", "NullTracer", "Span", "Tracer", "VIRTUAL", "WALL",
]
