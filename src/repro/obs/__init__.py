"""repro.obs — observability for the engine stack.

The cross-cutting layer that turns every run into trace data:

  * ``obs.trace`` — nested spans (``stage`` > ``round`` > ``reduce[hop]``
    > ``reduce_leaf[leaf]``, plus ``local_steps`` / ``broadcast`` /
    ``merge``) on three clock domains: measured wall time, the event
    runtime's virtual clock, and the engine ledger's modeled α–β
    timeline. Zero overhead when disabled (``NULL_TRACER`` is falsy).
  * ``obs.metrics`` — process-local counters/gauges/histograms all three
    backends and the comm reducers report into; snapshotted into
    ``EngineReport.metrics``.
  * ``obs.series`` — ``(t, value)`` time series on the same three clocks
    with windowed derived views (rate, sliding mean/p50/p95/p99) and a
    strict clock-domain guard; the trajectory the point-in-time metrics
    can't show.
  * ``obs.slo`` — sliding-window SLO monitoring over serve series (p95
    TTFT / p99 e2e / throughput targets), breach spans on the virtual
    clock, and the open-loop saturation detector table6 reports.
  * ``obs.profile`` — ``jax.profiler`` session wrapper + block-until-
    ready wall timing for jitted steps; the modeled-vs-measured skew
    table behind ``launch/{train,serve}.py --profile``.
  * ``obs.export`` — JSONL span logs (round-tripping via ``read_jsonl``)
    and Chrome-trace/Perfetto JSON (one track per client/pod/leaf, spans
    colored by phase, one counter track per series) that
    https://ui.perfetto.dev opens directly.
  * ``obs.diff`` — schema-validated BENCH_*.json loading and numeric
    regression diffing (``tools/bench_diff.py``, CI).

See docs/observability.md for the span taxonomy, metric/unit tables and
the Perfetto walkthrough.
"""
from repro.obs.diff import (
    BenchSchemaError,
    Delta,
    DIFF_KEYS,
    DirDiff,
    diff_benches,
    diff_dirs,
    load_bench,
    row_key,
    validate_bench,
)
from repro.obs.export import (
    read_jsonl,
    span_record,
    to_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    registry,
    reset,
)
from repro.obs.profile import ProfileSession, StepTiming, format_skew_table
from repro.obs.series import ClockDomainError, Series, SeriesRegistry
from repro.obs.slo import SLOBreach, SLOMonitor, SLOTarget, serve_slo_targets
from repro.obs.trace import (
    CAT_COMM,
    CAT_COMPUTE,
    CAT_CONTROL,
    CAT_MERGE,
    MODELED,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    VIRTUAL,
    WALL,
)

__all__ = [
    "BenchSchemaError", "Delta", "DIFF_KEYS", "DirDiff", "diff_benches",
    "diff_dirs", "load_bench", "row_key", "validate_bench",
    "read_jsonl", "span_record", "to_chrome_trace", "write_chrome_trace",
    "write_jsonl",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry", "reset",
    "ProfileSession", "StepTiming", "format_skew_table",
    "ClockDomainError", "Series", "SeriesRegistry",
    "SLOBreach", "SLOMonitor", "SLOTarget", "serve_slo_targets",
    "CAT_COMM", "CAT_COMPUTE", "CAT_CONTROL", "CAT_MERGE", "MODELED",
    "NULL_TRACER", "NullTracer", "Span", "Tracer", "VIRTUAL", "WALL",
]
