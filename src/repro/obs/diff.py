"""BENCH_*.json loading, schema validation and numeric regression diffing.

The perf-trajectory artifacts (``benchmarks.common.save_bench``) are the
repo's committed performance baselines; this module is what CI and
``tools/bench_diff.py`` use to compare a fresh run against them:

  * ``load_bench`` — parse + schema-validate one BENCH_*.json file
    (schema v1: ``{"bench", "schema", "meta", "rows"}``, rows a list of
    flat dicts);
  * ``diff_benches`` — match rows across two artifacts by their identity
    columns and flag any *monitored* numeric column (modeled comm bytes,
    modeled seconds, rounds, modeled wall-clock) that regressed beyond a
    configurable relative tolerance;
  * ``diff_dirs`` — the directory sweep CI runs: every artifact present
    in both trees is diffed; artifacts whose ``meta.scale`` differs are
    skipped (a smoke run must not be judged against a full-protocol
    baseline).

A *regression* is ``current > baseline × (1 + tol)`` — more modeled
bytes/seconds/rounds than the committed trajectory allows. Improvements
are reported (so the baseline can be re-committed) but never fail.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

# columns that identify a row within its bench (whichever are present)
ID_KEYS = ("dataset", "net", "dist", "algo", "mode", "reducer", "schedule",
           "slowdown", "leaves", "arch", "shape", "program", "cell")

# monitored numeric columns: modeled comm bytes/seconds, round counts, the
# event runtime's modeled wall-clock, the serving driver's modeled latency
# percentiles and its total SLO-breach seconds — higher is worse for all
# of them (time-to-breach is higher-is-better and therefore NOT gated;
# the breach-seconds column catches the same saturation regressions)
DIFF_KEYS = ("comm_bytes", "comm_time_s", "rounds", "wall_clock_s",
             "blocking_s", "streaming_s", "p50_s", "p95_s", "p99_s",
             "slo_breach_s")


class BenchSchemaError(ValueError):
    """A BENCH_*.json file that does not match schema v1."""


def validate_bench(rec: dict, path: str = "<bench>") -> dict:
    """Validate one parsed BENCH record against schema v1; returns it
    (with ``meta`` defaulted) or raises ``BenchSchemaError``."""
    if not isinstance(rec, dict):
        raise BenchSchemaError(f"{path}: expected a JSON object, got "
                               f"{type(rec).__name__}")
    for key, typ in (("bench", str), ("schema", int), ("rows", list)):
        if key not in rec:
            raise BenchSchemaError(f"{path}: missing required key {key!r}")
        if not isinstance(rec[key], typ):
            raise BenchSchemaError(
                f"{path}: key {key!r} must be {typ.__name__}, got "
                f"{type(rec[key]).__name__}")
    if rec["schema"] != 1:
        raise BenchSchemaError(
            f"{path}: unsupported schema version {rec['schema']} "
            f"(this reader knows schema 1)")
    for i, row in enumerate(rec["rows"]):
        if not isinstance(row, dict):
            raise BenchSchemaError(f"{path}: rows[{i}] is not an object")
    rec.setdefault("meta", {})
    if not isinstance(rec["meta"], dict):
        raise BenchSchemaError(f"{path}: meta must be an object")
    return rec


def load_bench(path: str) -> dict:
    """Load + validate one BENCH_*.json artifact."""
    with open(path) as f:
        try:
            rec = json.load(f)
        except json.JSONDecodeError as e:
            raise BenchSchemaError(f"{path}: not valid JSON ({e})") from None
    return validate_bench(rec, path)


def row_key(row: dict) -> Tuple[Tuple[str, str], ...]:
    """Identity of one row: the present ID_KEYS columns, stringified."""
    return tuple((k, str(row[k])) for k in ID_KEYS if k in row)


def _num(row: dict, key: str) -> Optional[float]:
    v = row.get(key)
    if v is None:
        return None
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


@dataclass(frozen=True)
class Delta:
    """One (row, column) comparison between baseline and current."""

    bench: str
    cell: str               # rendered row identity
    key: str                # monitored column name
    baseline: float
    current: float

    @property
    def ratio(self) -> float:
        return self.current / self.baseline if self.baseline else float(
            "inf") if self.current else 1.0

    def regressed(self, tol: float) -> bool:
        return self.current > self.baseline * (1.0 + tol) \
            and self.current - self.baseline > 1e-12

    def improved(self, tol: float) -> bool:
        return self.current < self.baseline * (1.0 - tol)

    def render(self) -> str:
        return (f"{self.bench} [{self.cell}] {self.key}: "
                f"{self.baseline:g} -> {self.current:g} "
                f"({self.ratio:.3f}x)")


def diff_benches(baseline: dict, current: dict, *,
                 keys: Sequence[str] = DIFF_KEYS) -> List[Delta]:
    """All monitored-column deltas between two validated BENCH records.

    Rows are matched by ``row_key``; rows present on only one side are
    ignored (coverage changes are not regressions). Columns missing on
    either side are skipped — pre-PR-1 artifacts without comm fields
    simply contribute no comm deltas.
    """
    base_rows: Dict[tuple, dict] = {row_key(r): r for r in baseline["rows"]}
    out: List[Delta] = []
    for row in current["rows"]:
        k = row_key(row)
        b = base_rows.get(k)
        if b is None:
            continue
        cell = " ".join(v for _, v in k) or "-"
        for key in keys:
            bv, cv = _num(b, key), _num(row, key)
            if bv is None or cv is None:
                continue
            out.append(Delta(bench=current.get("bench", "?"), cell=cell,
                             key=key, baseline=bv, current=cv))
    return out


@dataclass
class DirDiff:
    """Result of diffing a run directory against a baseline directory."""

    deltas: List[Delta]
    compared: List[str]     # artifact basenames diffed
    skipped: List[str]      # "<name>: reason" for unmatched/mismatched files

    def regressions(self, tol: float) -> List[Delta]:
        return [d for d in self.deltas if d.regressed(tol)]

    def improvements(self, tol: float) -> List[Delta]:
        return [d for d in self.deltas if d.improved(tol)]


def diff_dirs(baseline_dir: str, current_dir: str, *,
              keys: Sequence[str] = DIFF_KEYS,
              pattern: str = "BENCH_*.json") -> DirDiff:
    """Diff every BENCH artifact present in both directories.

    Artifacts are matched by basename. A file whose ``meta.scale``
    disagrees with its baseline is skipped (never silently compared):
    smoke/quick/full protocols produce incommensurable numbers.
    """
    deltas: List[Delta] = []
    compared: List[str] = []
    skipped: List[str] = []
    base_files = {os.path.basename(p): p for p in
                  glob.glob(os.path.join(baseline_dir, pattern))}
    cur_files = sorted(glob.glob(os.path.join(current_dir, pattern)))
    for cur_path in cur_files:
        name = os.path.basename(cur_path)
        base_path = base_files.get(name)
        if base_path is None:
            skipped.append(f"{name}: no baseline")
            continue
        base = load_bench(base_path)
        cur = load_bench(cur_path)
        bs = base["meta"].get("scale")
        cs = cur["meta"].get("scale")
        if bs is not None and cs is not None and bs != cs:
            skipped.append(f"{name}: scale mismatch "
                           f"(baseline {bs!r} vs current {cs!r})")
            continue
        deltas.extend(diff_benches(base, cur, keys=keys))
        compared.append(name)
    for name in sorted(set(base_files) - {os.path.basename(p)
                                          for p in cur_files}):
        skipped.append(f"{name}: baseline only (bench not run)")
    return DirDiff(deltas=deltas, compared=compared, skipped=skipped)
