"""Span export: JSONL logs and Chrome-trace / Perfetto JSON timelines.

Serializations of a ``Tracer``'s span list (plus ``obs.series`` curves):

  * ``write_jsonl`` / ``read_jsonl`` — one JSON object per span (the raw
    log; greppable, diffable, append-friendly). The pair round-trips:
    ``read_jsonl(write_jsonl(tracer, p))`` reconstructs identical
    ``Span`` objects, so CI trace artifacts can be re-exported to
    Perfetto offline;
  * ``to_chrome_trace`` / ``write_chrome_trace`` — the Chrome Trace Event
    Format (JSON object with a ``traceEvents`` list) that
    https://ui.perfetto.dev opens directly. Each clock domain becomes one
    Perfetto *process* ("virtual clock", "modeled α–β timeline", "wall
    clock"), each span track one named *thread* row (``client/3``,
    ``leaf/2``, ``server``, …), spans are complete ("X") events colored
    by phase category, and span attributes land in ``args`` so clicking a
    ``reduce_leaf`` slice shows its leaf path, payload bytes and modeled
    seconds. Pass ``series=`` (a ``SeriesRegistry`` or list of
    ``Series``) to additionally render each series as a *counter track*
    ("C" events) inside its clock's process — queue depth, batch
    occupancy and tokens/s curves sit directly under the span waterfall
    that explains them.

Timestamps: Chrome traces count microseconds; all tracer clocks count
seconds, so every t0/duration is scaled by 1e6. Virtual/modeled traces
start at 0 by construction; wall spans (and wall series samples) are
rebased to the earliest wall timestamp so the three processes align at
t=0.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple, Union

from repro.obs.trace import (
    CAT_COMM,
    CAT_COMPUTE,
    CAT_CONTROL,
    CAT_MERGE,
    MODELED,
    VIRTUAL,
    WALL,
    Span,
    Tracer,
)

# clock domain -> (pid, Perfetto process name)
_PROCESSES = {
    VIRTUAL: (1, "virtual clock (event runtime)"),
    MODELED: (2, "modeled α–β timeline (engine ledger)"),
    WALL: (3, "wall clock (host)"),
}

# phase category -> Chrome reserved color name ("spans colored by phase")
_CNAME = {
    CAT_COMPUTE: "thread_state_running",   # green
    CAT_COMM: "rail_response",             # blue
    CAT_MERGE: "rail_animation",           # purple
    CAT_CONTROL: "grey",
}


def _spans(source: Union[Tracer, List[Span]]) -> List[Span]:
    return source.spans if isinstance(source, Tracer) else list(source)


def span_record(s: Span) -> dict:
    """One span as a plain JSON-serializable dict (the JSONL row)."""
    return {"id": s.id, "parent": s.parent, "name": s.name, "cat": s.cat,
            "track": s.track, "clock": s.clock, "t0": s.t0, "t1": s.t1,
            "attrs": s.attrs}


def write_jsonl(source: Union[Tracer, List[Span]], path: str) -> str:
    """Write the span log as JSON Lines (one span per line, id order)."""
    with open(path, "w") as f:
        for s in _spans(source):
            f.write(json.dumps(span_record(s), sort_keys=True,
                               default=str) + "\n")
    return path


def read_jsonl(path: str) -> List[Span]:
    """Load a ``write_jsonl`` span log back into ``Span`` objects.

    The inverse of ``span_record``: a written log reads back into spans
    whose ``key()`` fingerprints match the originals (for
    JSON-representable attribute values — anything else was stringified
    on write), so CI ``.jsonl`` artifacts re-export to Perfetto offline:
    ``write_chrome_trace(read_jsonl(p), out)``.
    """
    spans: List[Span] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            spans.append(Span(id=int(d["id"]), parent=int(d["parent"]),
                              name=d["name"], cat=d["cat"],
                              track=d["track"], clock=d["clock"],
                              t0=float(d["t0"]), t1=float(d["t1"]),
                              attrs=dict(d.get("attrs") or {})))
    return spans


def _series_list(series) -> list:
    """Normalize the ``series=`` argument: None, one Series, a list, or a
    SeriesRegistry (anything iterable yielding Series)."""
    if series is None:
        return []
    if hasattr(series, "samples") and hasattr(series, "clock"):
        return [series]
    return list(series)


def _track_ids(spans: List[Span]) -> Dict[Tuple[str, str], int]:
    """(clock, track) -> tid, assigned in sorted-name order per clock so
    Perfetto rows come out grouped and deterministic (server/engine rows
    first, then client/…, leaf/… lexicographically)."""
    tids: Dict[Tuple[str, str], int] = {}
    by_clock: Dict[str, set] = {}
    for s in spans:
        by_clock.setdefault(s.clock, set()).add(s.track)
    for clock, tracks in by_clock.items():
        for i, track in enumerate(sorted(tracks)):
            tids[(clock, track)] = i + 1
    return tids


def to_chrome_trace(source: Union[Tracer, List[Span]],
                    run_id: Optional[str] = None,
                    series=None) -> dict:
    """Render spans (and optional series) as a Chrome Trace Event object.

    Load the written file at https://ui.perfetto.dev (or
    chrome://tracing): one process per clock domain, one thread row per
    span track, durations in microseconds, attributes under ``args``.
    ``series`` (a ``SeriesRegistry``, a list of ``Series``, or one
    ``Series``) adds one counter track per series — "C" events named by
    the series, one sample per recorded ``(t, value)``, in the process
    of the series' clock so counters align with the span timestamps.
    """
    spans = _spans(source)
    srs = _series_list(series)
    if run_id is None and isinstance(source, Tracer):
        run_id = source.run_id
    tids = _track_ids(spans)
    wall0 = min((s.t0 for s in spans if s.clock == WALL), default=None)
    if wall0 is None:
        wall0 = min((t for sr in srs if sr.clock == WALL
                     for t, _ in sr.samples()), default=0.0)
    events: List[dict] = []
    seen_proc = set()
    for (clock, track), tid in sorted(tids.items(),
                                      key=lambda kv: (kv[0][0], kv[1])):
        pid, pname = _PROCESSES[clock]
        if pid not in seen_proc:
            seen_proc.add(pid)
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": pname}})
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": track}})
    for sr in srs:
        pid, pname = _PROCESSES[sr.clock]
        if pid not in seen_proc:
            seen_proc.add(pid)
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": pname}})
        base = wall0 if sr.clock == WALL else 0.0
        for t, v in sr.samples():
            events.append({"ph": "C", "name": sr.name, "pid": pid,
                           "tid": 0, "ts": (t - base) * 1e6,
                           "args": {"value": v}})
    for s in spans:
        pid, _ = _PROCESSES[s.clock]
        t0 = s.t0 - (wall0 if s.clock == WALL else 0.0)
        ev = {"ph": "X", "name": s.name, "cat": s.cat, "pid": pid,
              "tid": tids[(s.clock, s.track)],
              "ts": t0 * 1e6, "dur": max(s.t1 - s.t0, 0.0) * 1e6,
              "args": dict(s.attrs, span_id=s.id, clock=s.clock)}
        cname = _CNAME.get(s.cat)
        if cname:
            ev["cname"] = cname
        events.append(ev)
    trace = {"traceEvents": events, "displayTimeUnit": "ms",
             "otherData": {"producer": "repro.obs"}}
    if run_id:
        trace["otherData"]["run_id"] = run_id
    return trace


def write_chrome_trace(source: Union[Tracer, List[Span]], path: str,
                       run_id: Optional[str] = None, series=None) -> str:
    """Write ``to_chrome_trace`` output to ``path`` (Perfetto-loadable)."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(source, run_id=run_id, series=series), f,
                  default=str)
    return path
