"""Process-local metrics registry — the aggregate half of ``repro.obs``.

Counters (monotone sums), gauges (last-write-wins) and histograms
(count/sum/min/max summaries), each keyed by a metric name plus optional
labels. All three execution backends, the engine ledger and the
``comm.Reducer`` implementations report into one process-local default
registry; ``Engine.run`` snapshots it into ``EngineReport.metrics`` when
a run finishes.

Metric names use dotted namespaces (``engine.rounds``,
``comm.bytes``, ``runtime.merge_staleness``); units ride on the metric
object and in the snapshot so reports stay self-describing — see the
metric table in docs/observability.md.

This is deliberately not a Prometheus client: no locks (JAX host code is
single-threaded per process), no export protocol — ``snapshot()`` returns
plain dicts that serialize into BENCH/report artifacts.
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key) if key else ""


@dataclass
class Metric:
    """Base: one named family of labelled series."""

    name: str
    unit: str = ""
    help: str = ""
    kind: str = "metric"
    values: Dict[LabelKey, float] = field(default_factory=dict)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "unit": self.unit, "help": self.help,
                "values": {_label_str(k): v for k, v in
                           sorted(self.values.items())}}


@dataclass
class Counter(Metric):
    """Monotone sum (events, bytes, rounds)."""

    kind: str = "counter"

    def inc(self, value: float = 1.0, **labels):
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + value

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)


@dataclass
class Gauge(Metric):
    """Last-write-wins sample (per-stage objective, queue depth)."""

    kind: str = "gauge"

    def set(self, value: float, **labels):
        self.values[_label_key(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        return self.values.get(_label_key(labels))


PERCENTILES = (50.0, 95.0, 99.0)   # the tail summary every histogram carries


def _percentile(xs: List[float], q: float) -> float:
    """q-th percentile of ``xs`` with linear interpolation between closest
    ranks — numerically identical to ``numpy.percentile(xs, q)`` (the
    default "linear" method), which the unit tests pin."""
    ys = sorted(xs)
    if len(ys) == 1:
        return ys[0]
    rank = (q / 100.0) * (len(ys) - 1)
    lo = int(rank)
    frac = rank - lo
    if lo + 1 >= len(ys):
        return ys[-1]
    return ys[lo] + frac * (ys[lo + 1] - ys[lo])


@dataclass
class Histogram(Metric):
    """count/sum/min/max + p50/p95/p99 summary per label set (staleness,
    round times, request latencies).

    Raw samples are retained per label set *up to* ``cap`` (default
    4096): below it percentiles are exact (numpy-identical linear
    interpolation); past it the retained set becomes a uniform reservoir
    (Algorithm R, deterministically seeded per (metric, label set)) so
    memory stays O(cap) at cohort scale while percentiles degrade to an
    unbiased approximation — ``summary()`` flags this with
    ``approx: True``, never silently. count/sum/min/max stay exact at
    any volume. Bounded consumers that need exact tails (the serve
    latency ledger's p50/p95/p99 columns) pin a cap above their sample
    counts.
    """

    kind: str = "histogram"
    cap: int = 4096
    stats: Dict[LabelKey, dict] = field(default_factory=dict)
    samples: Dict[LabelKey, List[float]] = field(default_factory=dict)
    _rngs: Dict[LabelKey, random.Random] = field(default_factory=dict,
                                                 repr=False)

    def _rng(self, k: LabelKey) -> random.Random:
        rng = self._rngs.get(k)
        if rng is None:
            seed = zlib.crc32(f"{self.name}|{_label_str(k)}".encode())
            rng = self._rngs[k] = random.Random(seed)
        return rng

    def observe(self, value: float, **labels):
        v = float(value)
        k = _label_key(labels)
        st = self.stats.setdefault(k, {"count": 0, "sum": 0.0,
                                       "min": v, "max": v})
        st["count"] += 1
        st["sum"] += v
        st["min"] = min(st["min"], v)
        st["max"] = max(st["max"], v)
        xs = self.samples.setdefault(k, [])
        if len(xs) < self.cap:
            xs.append(v)
        else:
            # reservoir sampling (Algorithm R): keep each of the count
            # observations with equal probability cap/count
            j = self._rng(k).randrange(st["count"])
            if j < self.cap:
                xs[j] = v

    def percentile(self, q: float, **labels) -> Optional[float]:
        """Exact q-th percentile of everything observed under ``labels``
        (None when nothing was observed)."""
        xs = self.samples.get(_label_key(labels))
        return _percentile(xs, q) if xs else None

    def _full(self, k: LabelKey) -> dict:
        st = self.stats[k]
        out = dict(st)
        out["mean"] = st["sum"] / st["count"] if st["count"] else 0.0
        xs = self.samples.get(k)
        for q in PERCENTILES:
            out[f"p{q:g}"] = _percentile(xs, q) if xs else None
        # approx: percentiles come from a reservoir, not the full set
        out["approx"] = bool(xs is not None and st["count"] > len(xs))
        return out

    def summary(self, **labels) -> Optional[dict]:
        k = _label_key(labels)
        if k not in self.stats:
            return None
        return self._full(k)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "unit": self.unit, "help": self.help,
                "values": {_label_str(k): self._full(k)
                           for k, v in sorted(self.stats.items())}}


class MetricsRegistry:
    """Name → Metric map with idempotent, kind-checked registration."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, cls, name: str, unit: str, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name=name, unit=unit, help=help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.__name__.lower()}")
        return m

    def counter(self, name: str, unit: str = "", help: str = "") -> Counter:
        return self._get(Counter, name, unit, help)

    def gauge(self, name: str, unit: str = "", help: str = "") -> Gauge:
        return self._get(Gauge, name, unit, help)

    def histogram(self, name: str, unit: str = "", help: str = "",
                  cap: Optional[int] = None) -> Histogram:
        """``cap`` bounds retained raw samples (reservoir past it); only
        honored at first registration — registration stays idempotent."""
        kw = {} if cap is None else {"cap": cap}
        return self._get(Histogram, name, unit, help, **kw)

    def snapshot(self) -> dict:
        """Serializable view of every registered series, sorted by name."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def reset(self):
        """Drop all series (tests / run isolation)."""
        self._metrics.clear()

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]


_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-local default registry everything reports into."""
    return _DEFAULT


def reset():
    """Reset the default registry (run/test isolation)."""
    _DEFAULT.reset()
