"""Measured-time profiling — closing the loop on the modeled clocks.

Everything else in ``repro.obs`` prices runs on *modeled* clocks (the
α–β ledger, the roofline serve steps). This module measures the same
jitted steps on the host and reports the skew:

  * ``ProfileSession`` — a context manager that (optionally) wraps the
    run in a ``jax.profiler`` trace session (``logdir=`` writes the
    XPlane/TensorBoard artifact; unavailable profilers degrade to wall
    timing with a warning, never a crash) and records per-call
    block-until-ready wall timings next to their modeled prices;
  * ``skew_table()`` — per-step-name rows ``{name, calls, modeled_s,
    measured_s, skew}`` where ``skew = measured / modeled`` (>1: the
    model is optimistic; <1: the host beat the roofline — e.g. smoke
    shapes fitting in cache);
  * ``emit_spans()`` — one ``profile.<name>`` span per measured call on
    the **wall** clock carrying both ``modeled_s`` and ``measured_s``
    attrs. Wall spans are excluded from the determinism fingerprints by
    construction (``Span.key()``), so measured time still never leaks
    into the modeled/virtual ledgers.

Surfaced by ``launch/train.py --profile`` (jitted train/sync steps
against the DeviceModel roofline and the topology's α–β round price) and
``launch/serve.py --profile`` (prefill/decode steps against the serve
roofline).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

from repro.obs.trace import CAT_COMPUTE, WALL
from repro.utils.logging import get_logger

log = get_logger("obs.profile")

__all__ = ["ProfileSession", "StepTiming", "format_skew_table"]


def _block_until_ready(x):
    """Wait for every jax array in ``x`` (pass-through for host values)."""
    import jax

    try:
        return jax.block_until_ready(x)
    except Exception:
        # very old jax: per-leaf fallback
        for leaf in jax.tree_util.tree_leaves(x):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return x


@dataclass
class StepTiming:
    """One measured call of one profiled step."""

    name: str
    modeled_s: float           # the clock-domain price of this call
    measured_s: float          # block-until-ready host seconds
    t0: float                  # time.monotonic() at call start
    t1: float
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def skew(self) -> float:
        return (self.measured_s / self.modeled_s if self.modeled_s > 0
                else float("inf"))


class ProfileSession:
    """Collects modeled-vs-measured step timings for one run.

    Use as a context manager; with ``logdir`` set the session brackets
    the run in ``jax.profiler.start_trace``/``stop_trace`` (XPlane +
    trace.json.gz under ``logdir`` — TensorBoard/XProf-loadable). The
    wall-timing harness works regardless: ``step`` / ``wrap`` time each
    call with ``block_until_ready`` so async dispatch can't hide device
    time.
    """

    def __init__(self, logdir: Optional[str] = None):
        self.logdir = logdir
        self.records: List[StepTiming] = []
        self._tracing = False

    # -- jax.profiler session -----------------------------------------------

    def __enter__(self) -> "ProfileSession":
        if self.logdir:
            import jax

            try:
                jax.profiler.start_trace(self.logdir)
                self._tracing = True
            except Exception as e:  # backend without profiler support
                log.warning("profiler_unavailable", error=str(e),
                            logdir=self.logdir)
        return self

    def __exit__(self, *exc):
        if self._tracing:
            import jax

            try:
                jax.profiler.stop_trace()
            except Exception as e:
                log.warning("profiler_stop_failed", error=str(e))
            self._tracing = False
        return False

    # -- the wall-timing harness --------------------------------------------

    def measure(self, fn: Callable, *args, **kwargs):
        """Call ``fn`` and block until its outputs are ready; returns
        ``(out, t0, t1)`` on ``time.monotonic()``."""
        t0 = time.monotonic()
        out = _block_until_ready(fn(*args, **kwargs))
        return out, t0, time.monotonic()

    def record(self, name: str, modeled_s: float, measured_s: float,
               t0: float = 0.0, t1: float = 0.0, **attrs):
        self.records.append(StepTiming(name=name, modeled_s=float(modeled_s),
                                       measured_s=float(measured_s),
                                       t0=t0, t1=t1, attrs=attrs))

    def step(self, name: str, modeled_s: float, fn: Callable,
             *args, **kwargs):
        """Measure one call of ``fn`` against its modeled price."""
        out, t0, t1 = self.measure(fn, *args, **kwargs)
        self.record(name, modeled_s, t1 - t0, t0, t1)
        return out

    def wrap(self, fn: Callable, name: str,
             modeled_s: Union[float, Callable[..., float]]) -> Callable:
        """A call-compatible wrapper of ``fn`` that records every call.

        ``modeled_s`` is a constant price or a ``(*args, **kwargs) ->
        seconds`` callable evaluated per call. ``functools.wraps``
        preserves ``__wrapped__``, so tag-reading consumers
        (``local_sgd.sync_step_tags``) still see through the wrapper.
        """

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            price = (modeled_s(*args, **kwargs) if callable(modeled_s)
                     else modeled_s)
            return self.step(name, price, fn, *args, **kwargs)

        return wrapped

    # -- reporting ----------------------------------------------------------

    def skew_table(self) -> List[dict]:
        """Per-name totals: every profiled span carries both modeled and
        measured seconds; ``skew = measured / modeled``."""
        by: Dict[str, dict] = {}
        for r in self.records:
            row = by.setdefault(r.name, {"name": r.name, "calls": 0,
                                         "modeled_s": 0.0, "measured_s": 0.0})
            row["calls"] += 1
            row["modeled_s"] += r.modeled_s
            row["measured_s"] += r.measured_s
        out = []
        for name in sorted(by):
            row = by[name]
            row["skew"] = (row["measured_s"] / row["modeled_s"]
                           if row["modeled_s"] > 0 else float("inf"))
            out.append(row)
        return out

    def emit_spans(self, tracer, track: str = "profiler"):
        """Wall-clock ``profile.<name>`` spans, one per measured call,
        attrs carrying both timelines (``modeled_s`` / ``measured_s`` /
        ``skew``). Kept off the virtual/modeled clocks so measured time
        never enters the deterministic fingerprints."""
        if not tracer:
            return
        for r in self.records:
            tracer.add(f"profile.{r.name}", r.t0, r.t1, cat=CAT_COMPUTE,
                       track=track, clock=WALL,
                       attrs=dict(r.attrs, modeled_s=r.modeled_s,
                                  measured_s=r.measured_s, skew=r.skew))


def format_skew_table(rows: List[dict]) -> str:
    """Render ``skew_table()`` rows as an aligned text table."""
    if not rows:
        return "(no profiled steps)"
    lines = [f"{'step':<16} {'calls':>6} {'modeled_s':>12} "
             f"{'measured_s':>12} {'skew':>8}"]
    for r in rows:
        lines.append(f"{r['name']:<16} {r['calls']:>6d} "
                     f"{r['modeled_s']:>12.4e} {r['measured_s']:>12.4e} "
                     f"{r['skew']:>8.2f}")
    return "\n".join(lines)
