"""Time-series telemetry — the trajectory half of ``repro.obs``.

``obs.metrics`` answers "what were the totals"; this module answers "what
was the *curve*": a ``Series`` records ``(t, value)`` samples on exactly
one of the three clock domains (``virtual`` / ``modeled`` / ``wall``,
see ``obs.trace``), and a ``SeriesRegistry`` keys series by name with a
strict clock-domain guard — re-registering a name on a different clock
raises ``ClockDomainError`` instead of silently mixing timelines (a
virtual-clock queue-depth sample interleaved into a modeled-clock byte
curve would be meaningless and *look* plausible).

Emitters across the stack:

  * ``engine.Engine`` — per-round ``comm.round_bytes`` /
    ``comm.round_time_s`` / ``comm.cum_bytes`` and per-stage
    ``train.stage_objective`` vs ``train.stage_bytes`` on the modeled
    clock (the stagewise objective-vs-communication curve the paper is
    about);
  * ``runtime.EventBackend`` — ``runtime.active_clients``,
    ``runtime.inflight_merges``, ``runtime.merge_staleness``,
    ``runtime.round_time_s`` on the virtual clock;
  * ``serve.ServeEngine`` — ``serve.queue_depth``,
    ``serve.batch_occupancy``, ``serve.tokens_total`` (+ the derived
    ``serve.tokens_s`` rate) and the per-request ``serve.ttft_s`` /
    ``serve.e2e_s`` sample series on the virtual clock.

Derived views are *windowed*: ``rate`` (windowed average rate of a
cumulative counter), ``window_mean`` and ``window_percentile`` (sliding
p50/p95/p99 using the same linear interpolation as ``obs.metrics``, so
windowed and global percentiles never disagree on the same samples).
Each view returns a new ``Series`` on the same clock, so views compose
and export as counter tracks like any recorded series
(``obs.export.to_chrome_trace(..., series=...)``).

Determinism: series on the virtual/modeled clocks are a pure function of
(config, seed) — ``SeriesRegistry.fingerprint()`` is what the same-seed
tests compare. Samples recorded out of time order (e.g. request finish
times in id order) are sorted lazily and stably on read.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

from repro.obs.metrics import _percentile
from repro.obs.trace import CLOCKS

__all__ = ["ClockDomainError", "Series", "SeriesRegistry", "registry",
           "reset"]


class ClockDomainError(ValueError):
    """A series was requested on a different clock than it was registered
    on (or on a clock that does not exist)."""


class Series:
    """One named ``(t, value)`` sample stream on a single clock domain.

    ``max_samples`` bounds memory for open-ended emitters: past the cap
    further samples are *dropped* (counted in ``dropped``, surfaced in
    ``snapshot()``) — deterministic keep-first semantics, never silent.
    """

    __slots__ = ("name", "clock", "unit", "help", "max_samples", "dropped",
                 "_t", "_v", "_sorted")

    def __init__(self, name: str, clock: str, unit: str = "",
                 help: str = "", max_samples: Optional[int] = None):
        if clock not in CLOCKS:
            raise ClockDomainError(
                f"series {name!r}: unknown clock {clock!r} "
                f"(expected one of {CLOCKS})")
        self.name = name
        self.clock = clock
        self.unit = unit
        self.help = help
        self.max_samples = max_samples
        self.dropped = 0
        self._t: List[float] = []
        self._v: List[float] = []
        self._sorted = True

    def record(self, t: float, value: float):
        """Append one sample at time ``t`` (seconds on this clock)."""
        if self.max_samples is not None and len(self._t) >= self.max_samples:
            self.dropped += 1
            return
        t = float(t)
        if self._t and t < self._t[-1]:
            self._sorted = False
        self._t.append(t)
        self._v.append(float(value))

    # -- reads ---------------------------------------------------------------

    def _ensure_sorted(self):
        if not self._sorted:
            order = sorted(range(len(self._t)), key=lambda i: self._t[i])
            self._t = [self._t[i] for i in order]
            self._v = [self._v[i] for i in order]
            self._sorted = True

    def __len__(self) -> int:
        return len(self._t)

    def __bool__(self) -> bool:
        return True

    def samples(self) -> List[Tuple[float, float]]:
        """All samples, sorted by time (stable for ties)."""
        self._ensure_sorted()
        return list(zip(self._t, self._v))

    def times(self) -> List[float]:
        self._ensure_sorted()
        return list(self._t)

    def values(self) -> List[float]:
        self._ensure_sorted()
        return list(self._v)

    def last(self) -> Optional[Tuple[float, float]]:
        self._ensure_sorted()
        return (self._t[-1], self._v[-1]) if self._t else None

    def summary(self) -> dict:
        """Whole-series aggregate (count / min / max / mean / last)."""
        vs = self.values()
        out = {"count": len(vs), "dropped": self.dropped}
        if vs:
            out.update(min=min(vs), max=max(vs),
                       mean=sum(vs) / len(vs), last=vs[-1])
        return out

    # -- windowed derived views ---------------------------------------------

    def _windows(self, window_s: float) -> Iterator[Tuple[int, int]]:
        """(lo, i) index pairs: for each sample i, lo is the first index
        with ``t > t_i - window_s`` (two-pointer, O(n))."""
        self._ensure_sorted()
        lo = 0
        for i, t in enumerate(self._t):
            while self._t[lo] <= t - window_s:
                lo += 1
            yield lo, i

    def _derived(self, name: Optional[str], suffix: str, unit: str) -> "Series":
        return Series(name or f"{self.name}.{suffix}", self.clock,
                      unit=unit, help=f"{suffix} view of {self.name}")

    def rate(self, window_s: float, name: Optional[str] = None) -> "Series":
        """Windowed average rate of a cumulative counter: at each sample
        ``t_i``, ``(v_i − v_j) / (t_i − t_j)`` where ``j`` is the last
        sample at or before ``t_i − window_s`` (the first sample when the
        window reaches past the start). Zero-span windows yield no sample.
        """
        out = self._derived(name, "rate", f"{self.unit}/s" if self.unit
                            else "1/s")
        self._ensure_sorted()
        for i, t in enumerate(self._t):
            j = i
            while j > 0 and self._t[j - 1] > t - window_s:
                j -= 1
            j = max(0, j - 1) if j > 0 else 0
            dt = t - self._t[j]
            if dt > 0.0:
                out.record(t, (self._v[i] - self._v[j]) / dt)
        return out

    def window_mean(self, window_s: float,
                    name: Optional[str] = None) -> "Series":
        """Sliding-window mean: at each sample time, the mean of every
        sample inside ``(t − window_s, t]``."""
        out = self._derived(name, "mean", self.unit)
        acc = 0.0
        prev_lo = 0
        for lo, i in self._windows(window_s):
            acc += self._v[i]
            while prev_lo < lo:
                acc -= self._v[prev_lo]
                prev_lo += 1
            out.record(self._t[i], acc / (i - lo + 1))
        return out

    def window_percentile(self, q: float, window_s: float,
                          name: Optional[str] = None,
                          min_count: int = 1) -> "Series":
        """Sliding-window q-th percentile over ``(t − window_s, t]`` —
        same linear interpolation as ``obs.metrics`` histograms (numpy's
        default method), emitted only once the window holds at least
        ``min_count`` samples."""
        out = self._derived(name, f"p{q:g}", self.unit)
        for lo, i in self._windows(window_s):
            xs = self._v[lo:i + 1]
            if len(xs) >= min_count:
                out.record(self._t[i], _percentile(xs, q))
        return out

    # -- identity / serialization -------------------------------------------

    def fingerprint(self) -> tuple:
        """Deterministic identity (same-seed ⇒ identical fingerprints on
        the virtual/modeled clocks — what the determinism tests compare)."""
        return (self.name, self.clock, self.unit,
                tuple(self.samples()), self.dropped)

    def snapshot(self) -> dict:
        return {"clock": self.clock, "unit": self.unit, "help": self.help,
                "summary": self.summary()}


class SeriesRegistry:
    """Name → ``Series`` map with idempotent, clock-guarded registration.

    Mirrors ``MetricsRegistry``: asking for an existing name returns the
    existing series — but only on the clock it was registered on; a
    mismatch raises ``ClockDomainError`` (never silently re-clocks).
    """

    def __init__(self):
        self._series: Dict[str, Series] = {}

    def series(self, name: str, clock: str, unit: str = "", help: str = "",
               max_samples: Optional[int] = None) -> Series:
        s = self._series.get(name)
        if s is None:
            s = Series(name, clock, unit=unit, help=help,
                       max_samples=max_samples)
            self._series[name] = s
        elif s.clock != clock:
            raise ClockDomainError(
                f"series {name!r} already registered on clock "
                f"{s.clock!r}, requested {clock!r}")
        return s

    def add(self, series: Series) -> Series:
        """Insert an externally built series (e.g. a derived view). The
        same clock guard applies against any existing name."""
        cur = self._series.get(series.name)
        if cur is not None and cur.clock != series.clock:
            raise ClockDomainError(
                f"series {series.name!r} already registered on clock "
                f"{cur.clock!r}, adding {series.clock!r}")
        self._series[series.name] = series
        return series

    def get(self, name: str) -> Optional[Series]:
        return self._series.get(name)

    def names(self) -> List[str]:
        return sorted(self._series)

    def __contains__(self, name: str) -> bool:
        return name in self._series

    def __getitem__(self, name: str) -> Series:
        return self._series[name]

    def __iter__(self) -> Iterator[Series]:
        return iter([self._series[n] for n in self.names()])

    def __len__(self) -> int:
        return len(self._series)

    def fingerprint(self) -> dict:
        return {n: self._series[n].fingerprint() for n in self.names()}

    def snapshot(self) -> dict:
        """Serializable view (summaries only — samples stay in memory;
        export them as Perfetto counter tracks via ``obs.export``)."""
        return {n: self._series[n].snapshot() for n in self.names()}

    def reset(self):
        self._series.clear()


_DEFAULT = SeriesRegistry()


def registry() -> SeriesRegistry:
    """The process-local default series registry (mirrors
    ``obs.metrics.registry()``)."""
    return _DEFAULT


def reset():
    """Reset the default registry (run/test isolation)."""
    _DEFAULT.reset()
