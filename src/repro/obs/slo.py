"""Sliding-window SLO monitoring over ``obs.series`` telemetry.

An ``SLOTarget`` names one windowed aggregate of one series (p50/p95/p99
/ mean via ``Series.window_percentile`` / ``window_mean``, or ``rate``
via ``Series.rate`` for cumulative counters) and a threshold; the
``SLOMonitor`` evaluates every target over a ``SeriesRegistry`` and
merges consecutive breaching evaluations into ``SLOBreach`` intervals.

Everything here is a pure function of the recorded samples — evaluated
over virtual-clock serve series the breach intervals are deterministic
per traffic seed, which is why ``benchmarks/table6_serving.py`` can gate
its SLO columns (total breached seconds, time-to-breach) under the same
5% ``bench_diff`` tolerance as the latency percentiles.

The *saturation detector* is the open-loop question the monitor answers:
an SLO that breaches and never recovers before the trace ends means the
offered load exceeded capacity — ``saturated()`` is true iff some
target's last evaluation is still breaching. ``time_to_breach()`` is the
virtual time of the first breach (None below the knee).

Breach intervals export as ``slo_breach`` spans on the virtual clock
(``emit_spans``), so the Perfetto view shows *when* the tail blew up
right above the queue-depth counter track that explains why.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.obs.series import Series, SeriesRegistry
from repro.obs.trace import CAT_CONTROL, VIRTUAL

__all__ = ["SLOTarget", "SLOBreach", "SLOMonitor", "serve_slo_targets"]

_AGGS = ("mean", "p50", "p95", "p99", "rate")


@dataclass(frozen=True)
class SLOTarget:
    """One service-level objective on one series.

    Breach condition: windowed aggregate ``> threshold`` (or ``<`` with
    ``below=True`` — throughput floors). ``min_count`` delays percentile
    evaluation until the window holds enough samples to mean anything.
    """

    name: str                  # display name, e.g. "ttft_p95"
    series: str                # series name, e.g. "serve.ttft_s"
    agg: str                   # mean | p50 | p95 | p99 | rate
    threshold: float
    window_s: float
    min_count: int = 1
    below: bool = False        # breach when value drops under threshold

    def __post_init__(self):
        if self.agg not in _AGGS:
            raise ValueError(f"SLOTarget {self.name!r}: unknown agg "
                             f"{self.agg!r} (expected one of {_AGGS})")

    def view(self, series: Series) -> Series:
        """The windowed derived series this target evaluates."""
        if self.agg == "rate":
            return series.rate(self.window_s)
        if self.agg == "mean":
            return series.window_mean(self.window_s)
        q = float(self.agg[1:])
        return series.window_percentile(q, self.window_s,
                                        min_count=self.min_count)

    def breached(self, value: float) -> bool:
        return value < self.threshold if self.below \
            else value > self.threshold


@dataclass
class SLOBreach:
    """One maximal run of consecutive breaching evaluations."""

    target: str
    t0: float                  # first breaching evaluation time
    t1: float                  # last consecutive breaching evaluation time
    worst: float               # most-violating aggregate value inside
    n_evals: int = 0           # breaching evaluations merged into this
    open: bool = False         # still breaching at the last evaluation

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclass
class SLOMonitor:
    """Evaluates a set of targets over a SeriesRegistry."""

    targets: Sequence[SLOTarget]
    breaches: List[SLOBreach] = field(default_factory=list)
    # (target name, t, value, breached) — every evaluation, for tests/plots
    evaluations: List[Tuple[str, float, float, bool]] = field(
        default_factory=list)

    def evaluate(self, series: SeriesRegistry) -> List[SLOBreach]:
        """Evaluate every target; returns (and stores) breach intervals.

        A target whose series is absent contributes nothing (the monitor
        composes with partial telemetry); evaluation happens at each
        derived-view sample time, so the cadence is the series' own.
        """
        self.breaches = []
        self.evaluations = []
        for tgt in self.targets:
            src = series.get(tgt.series)
            if src is None or len(src) == 0:
                continue
            cur: Optional[SLOBreach] = None
            for t, v in tgt.view(src).samples():
                bad = tgt.breached(v)
                self.evaluations.append((tgt.name, t, v, bad))
                if bad:
                    if cur is None:
                        cur = SLOBreach(target=tgt.name, t0=t, t1=t,
                                        worst=v, n_evals=1)
                    else:
                        cur.t1 = t
                        cur.n_evals += 1
                        cur.worst = min(cur.worst, v) if tgt.below \
                            else max(cur.worst, v)
                elif cur is not None:
                    self.breaches.append(cur)
                    cur = None
            if cur is not None:
                cur.open = True
                self.breaches.append(cur)
        self.breaches.sort(key=lambda b: (b.t0, b.target))
        return self.breaches

    # -- derived verdicts ----------------------------------------------------

    def time_to_breach(self) -> Optional[float]:
        """Virtual time of the first breaching evaluation (None if every
        target held)."""
        return self.breaches[0].t0 if self.breaches else None

    def breach_seconds(self) -> float:
        """Total breached seconds summed over all intervals (the
        higher-is-worse column the bench gate monitors)."""
        return sum(b.duration_s for b in self.breaches)

    def saturated(self) -> bool:
        """True iff some target was still breaching at its last
        evaluation — the open-loop saturation signal (a transient burst
        breaches and recovers; past-capacity load never recovers)."""
        return any(b.open for b in self.breaches)

    def emit_spans(self, tracer, track: str = "slo"):
        """Lay one ``slo_breach`` span per interval on the virtual clock
        (zero-duration intervals export as instants)."""
        if not tracer:
            return
        for b in self.breaches:
            tracer.add("slo_breach", b.t0, b.t1, cat=CAT_CONTROL,
                       track=track, clock=VIRTUAL,
                       attrs={"target": b.target, "worst": b.worst,
                              "n_evals": b.n_evals, "open": b.open})

    def summary(self) -> dict:
        return {"targets": [t.name for t in self.targets],
                "n_breaches": len(self.breaches),
                "time_to_breach_s": self.time_to_breach(),
                "breach_seconds": self.breach_seconds(),
                "saturated": self.saturated()}


def serve_slo_targets(decode_step_s: float, *,
                      ttft_steps: float = 8.0,
                      e2e_steps: float = 22.0,
                      window_steps: float = 256.0,
                      min_count: int = 4,
                      tok_s_floor: Optional[float] = None,
                      ) -> List[SLOTarget]:
    """Default serve-stack SLOs, thresholds in units of the modeled
    decode step so they scale with the arch/pool instead of hard-coding
    seconds: p95 TTFT ≤ ``ttft_steps`` steps, p99 e2e ≤ ``e2e_steps``
    steps, and optionally a throughput floor (tokens/s over the
    cumulative ``serve.tokens_total`` counter — only meaningful when the
    offered load itself exceeds the floor, so off by default)."""
    w = window_steps * decode_step_s
    targets = [
        SLOTarget("ttft_p95", "serve.ttft_s", "p95",
                  ttft_steps * decode_step_s, w, min_count=min_count),
        SLOTarget("e2e_p99", "serve.e2e_s", "p99",
                  e2e_steps * decode_step_s, w, min_count=min_count),
    ]
    if tok_s_floor is not None:
        targets.append(SLOTarget("tok_s_min", "serve.tokens_total", "rate",
                                 tok_s_floor, w, min_count=1, below=True))
    return targets
