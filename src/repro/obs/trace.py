"""Span tracing — the timeline half of ``repro.obs``.

A ``Tracer`` records *spans*: named, attributed intervals on one of three
clock domains, nested into a tree by a begin/end stack:

  wall      measured ``time.monotonic()`` seconds (context-manager spans —
            stage execution, jit chunk calls, sync-step calls);
  virtual   the discrete-event runtime's modeled clock
            (``runtime.clock.Clock``) — client compute windows, uploads,
            per-leaf streaming arrivals, merges;
  modeled   the engine ledger's serial α–β timeline — per-round
            ``reduce[hop]`` / ``reduce_leaf[leaf]`` spans whose byte/second
            attributes reconcile with ``EngineReport.hop_costs`` /
            ``leaf_costs`` by construction.

Span taxonomy (see docs/observability.md for the full attribute table):
``run`` > ``stage`` > {``local_steps``, ``round`` > ``reduce`` >
``reduce_leaf``, ``broadcast``, ``merge``}.

Zero overhead when disabled: the module-level ``NULL_TRACER`` is falsy and
every emission site guards with ``if tracer: ...`` — a disabled run
executes one truthiness check per would-be span and allocates nothing.

Determinism: spans on the ``virtual`` and ``modeled`` clocks are a pure
function of (config, seeds) — same run ⇒ identical span tree including
timestamps (the property tests/test_obs.py pins); ``wall`` spans keep the
same tree *structure* but measured durations.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

WALL = "wall"
VIRTUAL = "virtual"
MODELED = "modeled"
CLOCKS = (WALL, VIRTUAL, MODELED)

# phase categories — the Chrome-trace color key (obs.export maps them)
CAT_COMPUTE = "compute"   # local SGD steps
CAT_COMM = "comm"         # uploads / reduces / broadcasts
CAT_CONTROL = "control"   # stages, rounds, barriers
CAT_MERGE = "merge"       # server-side merges (async arrival application)


@dataclass
class Span:
    """One recorded interval.

    ``t0``/``t1`` are seconds on the span's ``clock`` domain; ``track``
    names the Perfetto row the span renders on (``"engine"``,
    ``"client/3"``, ``"leaf/2"``, ``"server"``, …); ``parent`` is the
    index of the enclosing span in ``Tracer.spans`` (−1 at the root).
    """

    id: int
    parent: int
    name: str
    cat: str
    track: str
    clock: str
    t0: float
    t1: float
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def key(self):
        """Structural identity used by the determinism tests: everything
        except wall-clock timestamps (wall spans compare structurally,
        virtual/modeled spans timestamp-exactly)."""
        ts = (None, None) if self.clock == WALL else (self.t0, self.t1)
        return (self.id, self.parent, self.name, self.cat, self.track,
                self.clock) + ts + (tuple(sorted(
                    (k, v) for k, v in self.attrs.items())),)


class _NoopSpan:
    """Reusable no-op context manager returned by the null tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP_SPAN = _NoopSpan()


class NullTracer:
    """Disabled tracer: falsy, allocation-free, every method a no-op.

    Call sites keep the pattern ``if tracer: tracer.add(...)`` for hot
    loops and may call ``tracer.span(...)`` unconditionally (it returns a
    shared no-op context manager).
    """

    enabled = False
    spans: List[Span] = []

    def __bool__(self) -> bool:
        return False

    def span(self, *a, **kw):
        return _NOOP_SPAN

    def add(self, *a, **kw):
        return None

    def instant(self, *a, **kw):
        return None

    def begin(self, *a, **kw):
        return None

    def end(self, *a, **kw):
        return None


NULL_TRACER = NullTracer()


class _WallSpan:
    """Context manager measuring one wall-clock span on a Tracer."""

    __slots__ = ("tracer", "name", "cat", "track", "attrs", "_id", "_t0")

    def __init__(self, tracer, name, cat, track, attrs):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.track = track
        self.attrs = attrs

    def __enter__(self):
        self._t0 = time.monotonic()
        self._id = self.tracer._open(self.name, self.cat, self.track,
                                     WALL, self._t0, self.attrs)
        return self

    def __exit__(self, *exc):
        self.tracer._close(self._id, time.monotonic())
        return False

    def set(self, **attrs):
        """Attach attributes discovered mid-span (e.g. rounds executed)."""
        self.tracer.spans[self._id].attrs.update(attrs)
        return self


class Tracer:
    """Span recorder. Truthy; spans accumulate in ``self.spans`` in
    creation order (ids are list indices — stable and deterministic).

    Three emission styles:
      * ``with tracer.span("stage", ...):`` — wall-clock interval;
      * ``tracer.add("reduce", t0, t1, clock=MODELED, ...)`` — explicit
        timestamps on the virtual/modeled clocks;
      * ``tracer.begin/``end`` — explicit-time nesting for callers that
        interleave spans across clients (the event replay).
    Nesting: ``span``/``begin`` push onto one stack; ``add``/``instant``
    attach to whatever span is currently open.
    """

    def __init__(self, run_id: Optional[str] = None):
        self.run_id = run_id
        self.spans: List[Span] = []
        self._stack: List[int] = []

    enabled = True

    def __bool__(self) -> bool:
        return True

    # -- internals ----------------------------------------------------------

    def _open(self, name, cat, track, clock, t0, attrs) -> int:
        sid = len(self.spans)
        parent = self._stack[-1] if self._stack else -1
        self.spans.append(Span(id=sid, parent=parent, name=name, cat=cat,
                               track=track, clock=clock, t0=float(t0),
                               t1=float(t0), attrs=dict(attrs or {})))
        self._stack.append(sid)
        return sid

    def _close(self, sid: int, t1: float):
        self.spans[sid].t1 = float(t1)
        # close any children left open (defensive; normal use pops sid)
        while self._stack and self._stack[-1] != sid:
            self._stack.pop()
        if self._stack:
            self._stack.pop()

    # -- public API ---------------------------------------------------------

    def span(self, name: str, *, cat: str = CAT_CONTROL,
             track: str = "engine", attrs: Optional[dict] = None
             ) -> _WallSpan:
        """Wall-clock context-manager span (nested via the begin stack)."""
        return _WallSpan(self, name, cat, track, attrs)

    def begin(self, name: str, t0: float, *, cat: str = CAT_CONTROL,
              track: str = "engine", clock: str = VIRTUAL,
              attrs: Optional[dict] = None) -> int:
        """Open an explicit-time span; returns its id for ``end``."""
        return self._open(name, cat, track, clock, t0, attrs)

    def end(self, sid: int, t1: float):
        """Close a span opened with ``begin``."""
        self._close(sid, t1)

    def add(self, name: str, t0: float, t1: float, *,
            cat: str = CAT_COMM, track: str = "engine",
            clock: str = VIRTUAL, attrs: Optional[dict] = None) -> int:
        """Record one complete explicit-time span (child of the currently
        open span, if any)."""
        sid = self._open(name, cat, track, clock, t0, attrs)
        self._close(sid, t1)
        return sid

    def instant(self, name: str, t: float, *, cat: str = CAT_CONTROL,
                track: str = "engine", clock: str = VIRTUAL,
                attrs: Optional[dict] = None) -> int:
        """Zero-duration marker (e.g. ``broadcast`` at the merge point)."""
        return self.add(name, t, t, cat=cat, track=track, clock=clock,
                        attrs=attrs)

    # -- views --------------------------------------------------------------

    def find(self, name: str, clock: Optional[str] = None) -> List[Span]:
        """All spans named ``name`` (optionally on one clock domain)."""
        return [s for s in self.spans if s.name == name
                and (clock is None or s.clock == clock)]

    def children(self, span: Span) -> Iterator[Span]:
        return (s for s in self.spans if s.parent == span.id)

    def tree_keys(self) -> list:
        """Deterministic structural fingerprint of the whole span tree —
        what the same-seed ⇒ same-trace tests compare (wall timestamps
        excluded, virtual/modeled timestamps included)."""
        return [s.key() for s in self.spans]
