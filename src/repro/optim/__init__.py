from repro.optim.sgd import sgd_init, sgd_update, adamw_init, adamw_update, make_optimizer

__all__ = ["sgd_init", "sgd_update", "adamw_init", "adamw_update", "make_optimizer"]
