"""Optimizers (functional, pytree state) + factory.

SGD(+momentum) is the paper's optimizer; AdamW is provided for the LLM
training examples. Both expose (init, update) with the same signature so the
Local-SGD step builder is optimizer-agnostic. Optimizer state is averaged at
communication rounds alongside parameters (DESIGN.md §2) so k=1 Local SGD is
bit-identical to SyncSGD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def sgd_init(params):
    return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}


def sgd_update(params, grads, state, *, eta, momentum: float = 0.0,
               weight_decay: float = 0.0):
    def upd(p, g, m):
        g32 = g.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        m2 = momentum * m + g32
        p2 = p.astype(jnp.float32) - eta * m2
        return p2.astype(p.dtype), m2

    out = jax.tree.map(upd, params, grads, state["mu"])
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"mu": new_m}


def adamw_init(params):
    z = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.float32)}


def adamw_update(params, grads, state, *, eta, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay: float = 0.0):
    t = state["t"] + 1.0

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - eta * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda t_: t_[i], out, is_leaf=lambda t_: isinstance(t_, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "t": t}


def make_optimizer(name: str, momentum: float = 0.0, weight_decay: float = 0.0):
    """Returns (init_fn, update_fn(params, grads, state, eta))."""
    if name == "sgd":
        def update(params, grads, state, eta):
            return sgd_update(params, grads, state, eta=eta,
                              momentum=momentum, weight_decay=weight_decay)
        return sgd_init, update
    if name == "adamw":
        def update(params, grads, state, eta):
            return adamw_update(params, grads, state, eta=eta,
                                weight_decay=weight_decay)
        return adamw_init, update
    raise ValueError(name)
