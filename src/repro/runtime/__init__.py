# Discrete-event heterogeneous-client runtime: a virtual clock + per-client
# processes (sampled compute rates, α–β network draws, dropout) behind an
# EventBackend that plugs into repro.engine.Engine.run exactly like the
# vmapped simulator — synchronous policies replay barrier rounds on the
# clock (bit-exact numerics), AsyncPeriod policies merge uploads on arrival
# through comm.StalenessWeightedMean.
from repro.runtime.client import ClientProcess, Heterogeneity, sample_clients
from repro.runtime.clock import Clock, Event, EventQueue
from repro.runtime.runtime import (
    EventBackend,
    RuntimeResult,
    run,
    staleness_reducer_for,
)

__all__ = [
    "ClientProcess",
    "Clock",
    "Event",
    "EventBackend",
    "EventQueue",
    "Heterogeneity",
    "RuntimeResult",
    "run",
    "sample_clients",
    "staleness_reducer_for",
]
