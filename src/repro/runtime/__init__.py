# Discrete-event heterogeneous-client runtime: a virtual clock + per-client
# processes (sampled compute rates, α–β network draws, dropout) behind an
# EventBackend that plugs into repro.engine.Engine.run exactly like the
# vmapped simulator — synchronous policies replay barrier rounds on the
# clock (bit-exact numerics), AsyncPeriod policies merge uploads on arrival
# through comm.StalenessWeightedMean. Upload schedules decide how round-end
# messages meet the clock: BlockingSchedule (one monolithic message) or
# StreamingSchedule (per-leaf uploads overlapping the final local step).
from repro.runtime.client import ClientProcess, Heterogeneity, sample_clients
from repro.runtime.clock import Clock, Event, EventQueue
from repro.runtime.runtime import (
    EventBackend,
    RuntimeResult,
    run,
    staleness_reducer_for,
)
from repro.runtime.schedule import (
    BlockingSchedule,
    StreamingSchedule,
    UploadSchedule,
    get_schedule,
)

__all__ = [
    "BlockingSchedule",
    "ClientProcess",
    "Clock",
    "Event",
    "EventBackend",
    "EventQueue",
    "Heterogeneity",
    "RuntimeResult",
    "StreamingSchedule",
    "UploadSchedule",
    "get_schedule",
    "run",
    "sample_clients",
    "staleness_reducer_for",
]
