"""Per-client processes: sampled compute rates, network draws, dropout.

A ``ClientProcess`` is the runtime's unit of heterogeneity — each client
owns a compute rate (local steps per modeled second) and its own α–β
``NetworkModel`` uplink, drawn once per run from a ``Heterogeneity``
profile via a seeded numpy generator so the whole event trace is
reproducible from (config, seed).

The straggler model is the standard two-population one (cf. the
overhead-bounded Local SGD line in PAPERS.md): a ``straggler_frac``
fraction of clients runs ``straggler_slowdown``× slower; an optional
lognormal ``jitter`` roughens both the compute rates and the link
bandwidths of *all* clients. ``dropout`` is the per-upload probability
that a client's message is lost (sync: the client misses the round and
keeps its round-start params; async: the finished work is discarded and
the client re-pulls).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional

import numpy as np

from repro.comm.cost import NetworkModel, link_model

# salt separating the heterogeneity draws from TrainConfig.seed's jax streams
_HETERO_SEED_SALT = 0x0E7E


@dataclass(frozen=True)
class Heterogeneity:
    """Sampling profile for a population of clients."""

    base_step_time_s: float = 1e-3   # nominal wall-time of one local step
    straggler_frac: float = 0.0      # fraction of clients slowed down
    straggler_slowdown: float = 1.0  # their compute-rate divisor (1 = none)
    jitter: float = 0.0              # lognormal σ on rates and bandwidths
    dropout: float = 0.0             # P(an upload is lost)
    link: Optional[str] = None       # comm.link_model preset; None → network=
    seed: int = 0

    @property
    def enabled(self) -> bool:
        """Whether any draw can differ across clients / rounds."""
        return ((self.straggler_frac > 0.0 and self.straggler_slowdown != 1.0)
                or self.jitter > 0.0 or self.dropout > 0.0)

    @classmethod
    def from_config(cls, cfg) -> "Heterogeneity":
        """Build the profile from a TrainConfig's runtime fields."""
        return cls(base_step_time_s=cfg.base_step_time_s,
                   straggler_frac=cfg.straggler_frac,
                   straggler_slowdown=cfg.straggler_slowdown,
                   jitter=cfg.compute_jitter, dropout=cfg.dropout_rate,
                   seed=cfg.seed)

    def replace(self, **kw) -> "Heterogeneity":
        """Functional update (dataclasses.replace) of profile fields."""
        return replace(self, **kw)


@dataclass(frozen=True)
class ClientProcess:
    """One simulated client: its clock-relevant parameters only (model
    state lives in the backend; processes are pure cost descriptors)."""

    cid: int
    rate: float                       # relative compute speed, 1.0 = nominal
    step_time_s: float                # modeled seconds per local step
    network: NetworkModel = field(default_factory=NetworkModel)
    straggler: bool = False

    def compute_time(self, n_steps: int) -> float:
        """Modeled seconds this client needs for ``n_steps`` local SGD
        steps (``n_steps × step_time_s``; stragglers have larger
        step_time_s)."""
        return n_steps * self.step_time_s

    def upload_time(self, n_bytes: float) -> float:
        """Modeled seconds to ship ``n_bytes`` payload bytes over this
        client's α–β uplink (one latency α + serialization at β)."""
        return self.network.time(n_bytes)


def sample_clients(n: int, hetero: Heterogeneity,
                   network: Optional[NetworkModel] = None
                   ) -> List[ClientProcess]:
    """Draw n ClientProcesses from the profile (deterministic in seed).

    The base uplink is ``hetero.link``'s calibrated preset when set, else
    the ``network`` argument (a TrainConfig's comm_* model), else the
    default WAN. All draws come from one seeded RandomState in a fixed
    order, so the cohort is a pure function of (n, hetero, network).
    """
    base_net = (link_model(hetero.link) if hetero.link is not None
                else (network or NetworkModel()))
    rng = np.random.RandomState((hetero.seed + _HETERO_SEED_SALT) % (2 ** 31))
    n_strag = int(round(hetero.straggler_frac * n))
    stragglers = set(rng.choice(n, size=n_strag, replace=False).tolist()
                     if n_strag else [])
    clients = []
    for cid in range(n):
        rate = 1.0
        bw = base_net.bandwidth_gbps
        if hetero.jitter > 0.0:
            rate /= float(np.exp(rng.normal(0.0, hetero.jitter)))
            bw /= float(np.exp(rng.normal(0.0, hetero.jitter)))
        is_strag = cid in stragglers
        if is_strag:
            rate /= hetero.straggler_slowdown
        clients.append(ClientProcess(
            cid=cid, rate=rate,
            step_time_s=hetero.base_step_time_s / rate,
            network=NetworkModel(latency_s=base_net.latency_s,
                                 bandwidth_gbps=bw,
                                 count_downlink=base_net.count_downlink),
            straggler=is_strag))
    return clients
