"""Virtual clock + deterministic event queue for the discrete-event runtime.

The runtime's time is *modeled*, not measured: every client process and
network transfer schedules events on one global ``EventQueue``; the
``Clock`` advances monotonically to each popped event's timestamp. Events
with identical timestamps pop in insertion order (a monotonically
increasing sequence number breaks ties), so a run's event trace is a pure
function of its configuration and seeds — the property the dropout
determinism tests pin.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union


@dataclass(order=True, frozen=True)
class Event:
    """One scheduled occurrence. Ordering: (time, seq) — kind/client/info
    never participate in comparisons, so heap order is deterministic."""

    time: float                             # modeled seconds
    seq: int                                # insertion order (tie-break)
    # "compute_done" | "arrival" | "leaf_arrival" (streaming uploads,
    # info=(leaf index,)) | "merge" | "dropout" | "drop" | ...
    kind: str = field(compare=False)
    client: int = field(compare=False, default=-1)
    info: tuple = field(compare=False, default=())

    @property
    def leaf(self) -> Optional[int]:
        """Leaf index of a streaming ``leaf_arrival`` (None otherwise) —
        what attributes the event to a ``reduce_leaf`` span in
        ``repro.obs`` traces."""
        return self.info[0] if self.kind == "leaf_arrival" and self.info \
            else None


class EventQueue:
    """Min-heap of Events with deterministic FIFO tie-breaking."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client: int = -1,
             info: tuple = ()) -> Event:
        """Schedule an event at ``time`` modeled seconds; same-time events
        pop in push order (the monotone ``seq`` breaks ties)."""
        ev = Event(time=float(time), seq=self._seq, kind=kind, client=client,
                   info=info)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Remove and return the earliest scheduled event."""
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        """The earliest scheduled event without removing it (None if
        empty)."""
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class Clock:
    """Monotone virtual time in modeled seconds."""

    def __init__(self):
        self.now = 0.0

    def advance(self, t: float) -> float:
        """Move to (at least) time t; time never flows backwards."""
        self.now = max(self.now, float(t))
        return self.now


# (time_s, kind, client[, leaf index]) — streaming "leaf_arrival" entries
# carry the leaf index as a fourth element
TraceEntry = Union[Tuple[float, str, int], Tuple[float, str, int, int]]
