"""EventBackend — discrete-event execution backend for heterogeneous clients.

The third `Engine.run` backend (next to ``core.simulate.VmapSimulatorBackend``
and ``core.stl_sgd.DriverBackend``): every client is a simulated process
with its own compute rate and α–β uplink, and a virtual clock prices the
run in *modeled wall-clock seconds* instead of round counts — the missing
axis for comparing STL-SGD's growing k_s against asynchronous merging under
stragglers.

Two execution regimes, selected by the Algorithm's SyncPolicy:

  synchronous (EveryStep / FixedPeriod / Stagewise* / AdaptivePeriod)
      Numerics are *identical* to the vmapped simulator — with dropout
      disabled the backend delegates stage execution to
      ``VmapSimulatorBackend.run_stage`` unchanged, so the trajectory is
      bit-exact with the golden engine traces. The event layer replays each
      executed round on the clock through an *upload schedule*
      (``runtime.schedule``): blocking rounds emit per-client compute-done
      and arrival events and a barrier merge at the latest arrival
      (stragglers stretch every round); streaming rounds
      (``cfg.upload_schedule="streaming"``) emit per-leaf arrivals that
      start during the final local step, pricing communication/compute
      overlap — clock only, trajectories stay bit-exact across schedules.
      With ``dropout > 0`` a per-(round, client) mask freezes
      dropped clients for the round; the reduce still spans all N replicas
      (a dropped client contributes a zero delta — error-feedback safe, and
      composes with hierarchical topologies).

  asynchronous (AsyncPeriod — ``engine.make_async`` / ``cfg.async_mode``)
      No barrier: the stage's budget of N·T_s local steps is consumed
      greedily. Each client loops pull → k local steps → upload; the server
      merges each message on arrival through a
      ``comm.StalenessWeightedMean`` reducer (staleness counted in server
      cycles, error-feedback residuals per client, dense or int8 messages).
      Fast clients contribute more steps; stragglers' late deltas are
      staleness-decayed instead of stalling the cohort.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.cost import NetworkModel
from repro.comm.reducer import (DenseMean, StalenessWeightedMean,
                                get_reducer, supports_leaf_bytes)
from repro.configs.base import TrainConfig
from repro.core.simulate import (
    _COMM_SALT,
    Record,
    VmapSimulatorBackend,
    client_sgd_step,
    make_batch_weights,
    make_round_fn,
)
from repro.engine.algorithm import get_algorithm, make_async
from repro.engine.engine import Engine, StageStatus
from repro.engine.topology import Hierarchical, Star
from repro.obs.trace import CAT_COMM, CAT_COMPUTE, CAT_CONTROL, CAT_MERGE, VIRTUAL
from repro.runtime.client import Heterogeneity, sample_clients
from repro.runtime.clock import Clock, EventQueue, TraceEntry
from repro.runtime.schedule import UploadSchedule, get_schedule
from repro.utils.logging import get_logger
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading

log = get_logger("runtime")

# numpy stream salt for the dropout draws (separate from the client sampler)
_DROPOUT_SEED_SALT = 0x0D0D


def staleness_reducer_for(cfg: TrainConfig, reducer=None) -> StalenessWeightedMean:
    """Async merge reducer from a TrainConfig.

    ``cfg.reducer`` (or the explicit ``reducer`` spec) picks the message
    compression — dense f32 deltas or int<b> stochastic-rounding codes (the
    same kernels as ``QuantizedMean``); ``cfg.staleness_decay`` sets the
    (1+τ)^(−decay) merge weight. Top-k has no merge-on-arrival encoding.
    Only the barrier-spec → staleness-spec mapping lives here; the spec
    grammar itself is ``comm.get_reducer``'s.
    """
    spec = reducer if reducer is not None else cfg.reducer
    if isinstance(spec, StalenessWeightedMean):
        return spec
    if spec in (None, "dense", "mean"):
        spec = "staleness"
    elif spec in ("quant", "quantized"):
        spec = f"staleness-int{cfg.quant_bits}"
    elif isinstance(spec, str) and spec.startswith("int"):
        spec = f"staleness-{spec}"
    if not (isinstance(spec, str) and spec.startswith("staleness")):
        raise ValueError(
            f"async rounds carry dense or int<b> messages, got "
            f"reducer {spec!r}")
    return get_reducer(spec, staleness_decay=cfg.staleness_decay,
                       quant_bits=cfg.quant_bits)


class EventBackend(VmapSimulatorBackend):
    """Engine backend: simulated clients on a shared discrete-event clock.

    Heterogeneity disabled ⇒ the synchronous path is bit-exact with
    ``VmapSimulatorBackend`` (pinned against the PR 2 golden traces); the
    clock then simply prices homogeneous barrier rounds. Extra attributes
    after a run: ``clock.now`` (modeled seconds), ``trace`` (the event
    log), ``timeline`` ((time_s, round, objective) samples).
    """

    def __init__(self, loss_fn, init_params, client_data, eval_fn, *,
                 hetero: Optional[Heterogeneity] = None, merge_reducer=None,
                 schedule=None, eval_every: int = 1,
                 max_rounds: Optional[int] = None,
                 target: Optional[float] = None, lr_alpha: float = 0.0,
                 chunk_rounds: int = 32):
        super().__init__(loss_fn, init_params, client_data, eval_fn,
                         eval_every=eval_every, max_rounds=max_rounds,
                         target=target, lr_alpha=lr_alpha,
                         chunk_rounds=chunk_rounds)
        self._hetero_arg = hetero
        self._merge_arg = merge_reducer
        self._schedule_arg = schedule

    # -- setup ---------------------------------------------------------------

    def setup(self, engine: Engine):
        """Backend-contract setup: allocate simulator state (via the
        parent), sample the client cohort, build clock/queue/trace, and
        resolve the upload schedule + per-leaf payload/compute splits."""
        super().setup(engine)
        cfg = engine.cfg
        self.N = jax.tree.leaves(self.client_data)[0].shape[0]
        self.hetero = (self._hetero_arg if self._hetero_arg is not None
                       else Heterogeneity.from_config(cfg))
        net = NetworkModel(latency_s=cfg.comm_latency_s,
                           bandwidth_gbps=cfg.comm_bandwidth_gbps,
                           count_downlink=getattr(cfg, "count_downlink",
                                                  False))
        self.clients = sample_clients(self.N, self.hetero, net)
        self.clock = Clock()
        # runtime log records carry the virtual timestamp alongside the
        # host's monotonic one
        log.bind_clock(self.clock)
        self.queue = EventQueue()
        self.trace: List[TraceEntry] = []
        self.timeline: List[Tuple[float, int, float]] = [
            (0.0, 0, self.history[0].value)]
        self._np = np.random.RandomState(
            (self.hetero.seed + _DROPOUT_SEED_SALT) % (2 ** 31))
        self._round_times: List[float] = []
        self._stage_masks: List[np.ndarray] = []
        self._tracer = engine.tracer
        self._metrics = engine.metrics
        self._series = engine.series
        self.asynchronous = bool(
            getattr(engine.algorithm.sync_policy, "asynchronous", False))

        topo = engine.topology
        first_hop = getattr(topo, "reducer", None) or getattr(topo, "intra",
                                                              None)
        self._msg_bytes = first_hop.message_bytes(self.init_params)
        hops = topo.hop_costs(self.init_params, self.N)
        # hops beyond the uplink add to the barrier serially — except the
        # downlink, which broadcast_events prices per client after the
        # merge, and (below) a per-leaf-streamed WAN hop
        self._extra_hop_time = sum(h.time_s for h in hops[1:]
                                   if h.hop != "downlink")

        # upload schedule: what events one client's round-end message emits.
        # Per-leaf payload bytes come from the uplink reducer; per-leaf
        # compute fractions (share of one local step) from parameter counts.
        self.schedule: UploadSchedule = get_schedule(
            self._schedule_arg if self._schedule_arg is not None
            else getattr(cfg, "upload_schedule", None))
        if supports_leaf_bytes(first_hop):
            # explicit capability probe (not except NotImplementedError):
            # an exception from an *implemented* per-leaf method must
            # propagate, never degrade to monolithic blob pricing
            self._leaf_bytes = first_hop.leaf_message_bytes(self.init_params)
            sizes = [l.size for l in jax.tree.leaves(self.init_params)]
        else:
            if getattr(self.schedule, "streams_uplink", False):
                raise ValueError(
                    f"reducer {first_hop!r} has no per-leaf payload "
                    "accounting (leaf_message_bytes); streaming uploads "
                    "need it — implement the per-leaf protocol or use the "
                    "blocking schedule")
            # blocking schedules only ever sum the list: one opaque blob
            self._leaf_bytes, sizes = [self._msg_bytes], [1]
        total = float(sum(sizes))
        self._leaf_fracs = [s / total for s in sizes]
        # the downlink ships the dense consensus whatever the uplink
        # reducer; per-client pricing happens in schedule.broadcast_events
        self._down_bytes = DenseMean().leaf_message_bytes(self.init_params)
        self._ready = [0.0] * self.N   # per-client next-round start times
        # streaming∘hierarchical: the full streaming schedule forwards each
        # leaf over the inter-pod WAN link as soon as every pod holds it,
        # overlapping the WAN hop with the intra-pod reduction of the
        # remaining leaves (replacing the serial _extra_hop_time barrier add)
        self._stream_wan = (isinstance(topo, Hierarchical)
                            and getattr(self.schedule, "streams_round",
                                        False))
        if self._stream_wan:
            if not supports_leaf_bytes(topo.inter):
                raise ValueError(
                    f"inter-pod reducer {topo.inter!r} has no per-leaf "
                    "payload accounting (leaf_message_bytes); streaming "
                    "the WAN hop needs it — implement the per-leaf "
                    "protocol or use upload_schedule='streaming-uplink'")
            self._wan_leaf_bytes = [
                topo.n_pods * b
                for b in topo.inter.leaf_message_bytes(self.init_params)]
            self._wan_net = topo.inter_net
            self._extra_hop_time = 0.0
        if self.asynchronous and self.schedule.name != "blocking":
            raise ValueError(
                f"upload_schedule={self.schedule.name!r} prices per-leaf "
                "streaming of barriered rounds; AsyncPeriod merges whole "
                "messages on arrival — run streaming with a synchronous "
                "policy (drop async_mode / the '+async' suffix)")

        if self.asynchronous:
            red = self._merge_arg
            if red is None and isinstance(first_hop, StalenessWeightedMean):
                red = first_hop
            if red is None:
                red = staleness_reducer_for(cfg)
            self.merge_reducer: StalenessWeightedMean = red
            self._msg_bytes = red.message_bytes(self.init_params)
            # one merge = one client upload: re-price the engine ledger
            # per-message (the event clock owns end-to-end wall time)
            engine.set_cost_basis(self.init_params, 1)
            # the async path keeps per-client EF residuals (_c_res); the
            # stacked topology state super().setup() built would otherwise
            # pin ~N+1 unused model copies for the whole run
            self.comm_state = None
            self.server = self.init_params
            self.server_version = 0
            self._c_data = [jax.tree.map(lambda a: a[i], self.client_data)
                            for i in range(self.N)]
            self._c_params = [self.server] * self.N
            self._c_mom = [jax.tree.map(jnp.zeros_like, self.server)
                           for _ in range(self.N)]
            self._c_res = [red.client_residual(self.server)
                           for _ in range(self.N)]
            self._c_t = [jnp.zeros((), jnp.float32) for _ in range(self.N)]

    # -- synchronous regime --------------------------------------------------

    def run_stage(self, stage, engine: Engine) -> StageStatus:
        """Backend-contract stage execution: synchronous policies run the
        parent's numerics then replay the executed rounds on the clock;
        AsyncPeriod policies consume the stage budget merge-on-arrival."""
        if self.asynchronous:
            return self._run_stage_async(stage, engine)
        if self.hetero.dropout > 0.0 \
                and getattr(engine.algorithm.sync_policy, "adaptive", False):
            raise ValueError(
                "AdaptivePeriod's divergence probe assumes full "
                "participation; dropout composes with the fixed-period "
                "policies and the async runtime only")
        hist_mark = len(self.history)
        self._stage_masks = []
        # the parent runs the stage; dropout (if any) threads through via
        # the _chunk_fn/_sample_round_masks overrides below
        status = super().run_stage(stage, engine)
        if not self._stage_masks:  # full participation
            self._stage_masks = [np.ones(self.N, dtype=bool)
                                 for _ in self._last_round_steps]
        self._replay_rounds(self._last_round_steps, self._stage_masks)
        for rec in self.history[hist_mark:]:
            if rec.round >= 1:
                self.timeline.append(
                    (self._round_times[rec.round - 1], rec.round, rec.value))
        return status

    def _trace_client_round(self, tracer, c, start: float, kk: int,
                            events, active: bool):
        """Virtual-clock spans for one client's replayed barrier round:
        ``local_steps`` [round start, compute_done], then either one
        ``reduce`` upload span (blocking — the α–β transfer window) or one
        ``reduce_leaf`` serialization span per streamed leaf (the β window
        only; the stream's α is paid once at open and shows as the gap
        before the first leaf)."""
        track = f"client/{c.cid}"
        for t, kind, info in events:
            if kind == "compute_done":
                tracer.add("local_steps", start, t, cat=CAT_COMPUTE,
                           track=track, clock=VIRTUAL,
                           attrs={"steps": kk, "straggler": c.straggler})
            elif kind == "arrival":
                total = sum(self._leaf_bytes)
                tracer.add("reduce", t - c.upload_time(total), t,
                           cat=CAT_COMM, track=track, clock=VIRTUAL,
                           attrs={"bytes": total, "active": active})
            elif kind == "leaf_arrival":
                leaf = info[0]
                ser = self._leaf_bytes[leaf] / c.network.bandwidth_Bps
                tracer.add("reduce_leaf", t - ser, t, cat=CAT_COMM,
                           track=track, clock=VIRTUAL,
                           attrs={"leaf": leaf,
                                  "bytes": self._leaf_bytes[leaf],
                                  "active": active})

    def _vseries(self, name: str, unit: str, help: str):
        return self._series.series(name, clock=VIRTUAL, unit=unit, help=help)

    def _stream_wan_hop(self, leaf_max: List[float], tracer):
        """Stream the inter-pod WAN hop per leaf (streaming∘hierarchical).

        Leaf l can cross the WAN once every pod holds its reduced value —
        ``leaf_max[l]``, the latest intra-pod arrival. Leaves forward in
        server-completion (reverse-leaf) order over one serial WAN stream:
        α_wan is paid once when the stream opens, then each leaf
        serializes at β_wan as soon as it is ready and the link is free —
        so the WAN transfer of late-layer leaves overlaps the intra-pod
        reduction still in flight for the early layers. Returns
        ``(leaf_done, merge_t)``: per-leaf global-consensus times and the
        barrier merge (the last leaf's WAN landing).
        """
        net = self._wan_net
        link_free = None
        leaf_done = [0.0] * len(self._wan_leaf_bytes)
        merge_t = 0.0
        for leaf in range(len(self._wan_leaf_bytes) - 1, -1, -1):
            ready = leaf_max[leaf]
            if link_free is None:
                link_free = ready + net.latency_s  # WAN stream opens once
            send = max(ready, link_free)
            ser = self._wan_leaf_bytes[leaf] / net.bandwidth_Bps
            fin = send + ser
            link_free = fin
            leaf_done[leaf] = fin
            merge_t = max(merge_t, fin)
            self.trace.append((fin, "wan_leaf", -1, leaf))
            if tracer:
                tracer.add("reduce_leaf", fin - ser, fin, cat=CAT_COMM,
                           track="server/wan", clock=VIRTUAL,
                           attrs={"leaf": leaf, "hop": "inter_pod",
                                  "bytes": self._wan_leaf_bytes[leaf]})
        return leaf_done, merge_t

    def _broadcast_round(self, leaf_done: List[float], tracer) -> None:
        """Price each client's downlink and stage its next-round start.

        ``schedule.broadcast_events`` turns the server's per-leaf finish
        times into the client's broadcast arrivals (free on links that
        don't bill the downlink); the returned ready time is when that
        client may begin the next round's local compute. The events land
        in the trace with their (post-merge) timestamps but the clock is
        not advanced past the merge — the run's wall-clock is when the
        consensus exists at the server, and the next round's queue drain
        picks up from each client's ready time.
        """
        for c in self.clients:
            events, ready = self.schedule.broadcast_events(
                c, leaf_done, self._down_bytes)
            for t, kind, info in events:
                self.trace.append((t, kind, c.cid) + info)
                if not tracer:
                    continue
                if kind == "leaf_broadcast":
                    leaf = info[0]
                    ser = self._down_bytes[leaf] / c.network.bandwidth_Bps
                    tracer.add("broadcast_leaf", t - ser, t, cat=CAT_COMM,
                               track=f"client/{c.cid}", clock=VIRTUAL,
                               attrs={"leaf": leaf,
                                      "bytes": self._down_bytes[leaf]})
                else:  # broadcast_arrival: one monolithic transfer window
                    total = sum(self._down_bytes)
                    tracer.add("broadcast",
                               t - total / c.network.bandwidth_Bps, t,
                               cat=CAT_COMM, track=f"client/{c.cid}",
                               clock=VIRTUAL, attrs={"bytes": total})
            self._ready[c.cid] = ready

    def _replay_rounds(self, round_steps: List[int], masks: List[np.ndarray]):
        """Advance the event clock over the executed barrier rounds.

        Each client's round becomes events via the upload schedule —
        blocking: compute_done then one arrival; streaming: per-leaf
        arrivals that start during the final local step (the overlap the
        clock then prices). A dropped client skipped its local compute
        window but still answers the barrier with its zero-delta message,
        so it schedules upload-only arrivals. Client c's round starts at
        its own broadcast-ready time from the previous round (all equal
        to the previous merge when the downlink is unbilled); after the
        merge the downlink is priced per client via ``broadcast_events``.
        """
        tracer = self._tracer
        dropouts = self._metrics.counter(
            "runtime.dropout_events", unit="events",
            help="uploads lost / rounds missed to dropout")
        s_active = self._vseries(
            "runtime.active_clients", "clients",
            "clients participating in the barrier round / holding work")
        s_round = self._vseries(
            "runtime.round_time_s", "s",
            "virtual-clock duration of each barrier round")
        n_leaves = len(self._leaf_bytes)
        for kk, mask in zip(round_steps, masks):
            start = self.clock.now
            s_active.record(start, float(int(mask.sum())))
            rid = tracer.begin(
                "round", start, cat=CAT_CONTROL, track="server",
                clock=VIRTUAL,
                attrs={"k": kk, "schedule": self.schedule.name}) \
                if tracer else None
            for c in self.clients:
                active = bool(mask[c.cid])
                start_c = self._ready[c.cid]
                if not active:
                    self.trace.append((start_c, "dropout", c.cid))
                    dropouts.inc(mode="sync")
                    if tracer:
                        tracer.instant("dropout", start_c, cat=CAT_CONTROL,
                                       track=f"client/{c.cid}",
                                       clock=VIRTUAL)
                events, _ = self.schedule.round_events(
                    c, start_c, kk, self._leaf_bytes, self._leaf_fracs,
                    active=active)
                if tracer:
                    self._trace_client_round(tracer, c, start_c, kk, events,
                                             active)
                for t, kind, info in events:
                    self.queue.push(t, kind, c.cid, info)
            merge_t = start
            leaf_max = [start] * n_leaves
            while self.queue:
                ev = self.queue.pop()
                self.clock.advance(ev.time)
                # per-leaf events stay attributable: leaf_arrival entries
                # are (time, kind, client, leaf index)
                self.trace.append((ev.time, ev.kind, ev.client) + ev.info)
                merge_t = max(merge_t, ev.time)
                if ev.kind == "leaf_arrival":
                    leaf = ev.info[0]
                    leaf_max[leaf] = max(leaf_max[leaf], ev.time)
            if self._stream_wan:
                # per-leaf WAN forwarding replaces the serial barrier add
                leaf_done, merge_t = self._stream_wan_hop(leaf_max, tracer)
            elif getattr(self.schedule, "streams_round", False):
                # flat star: the server finishes leaf l at its last arrival
                merge_t += self._extra_hop_time
                leaf_done = leaf_max
            else:
                # blocking barrier (or uplink-only streaming): the whole
                # round merges at once, extra hops added serially
                merge_t += self._extra_hop_time
                leaf_done = [merge_t] * len(self._down_bytes)
            self.clock.advance(merge_t)
            self.trace.append((merge_t, "merge", -1))
            self._round_times.append(merge_t)
            s_round.record(merge_t, merge_t - start)
            if tracer:
                tracer.instant("broadcast", merge_t, cat=CAT_COMM,
                               track="server", clock=VIRTUAL)
            self._broadcast_round(leaf_done, tracer)
            if tracer:
                tracer.end(rid, merge_t)

    def _sample_round_masks(self, n: int):
        """Dropout masks for the parent's next n rounds (None = no dropout).

        Sampled from the backend's seeded numpy stream in execution order,
        so the masks — and therefore the trace and the trajectory — are a
        pure function of (config, seed).
        """
        if self.asynchronous or self.hetero.dropout <= 0.0:
            return None
        masks = self._np.random_sample((n, self.N)) >= self.hetero.dropout
        self._stage_masks.extend(masks)
        return masks

    def _chunk_fn(self, engine: Engine, k: int, b: int):
        """With dropout active, chunk through the mask-threaded round fn."""
        if self.asynchronous or self.hetero.dropout <= 0.0:
            return super()._chunk_fn(engine, k, b)
        key = ("masked", k, b)
        if key not in self._chunk_cache:
            cfg = engine.cfg
            round_fn = make_round_fn(
                self.wloss, k=k, batch=b, momentum=cfg.momentum,
                lr_alpha=self.lr_alpha, grow=self.grow,
                b0=cfg.batch_per_client, max_batch=cfg.max_batch,
                reducer=engine.topology, masked=True)
            eval_fn = self.eval_fn

            @partial(jax.jit, static_argnames=("n",))
            def chunk_fn(carry, rng_c, data, ctr, eta, masks, n):
                def body(c, xs):
                    rng_r, mask = xs
                    c = round_fn(c, rng_r, data, ctr, eta, mask)
                    return c, eval_fn(tree_mean_leading(c[0]))
                return jax.lax.scan(
                    body, carry, (jax.random.split(rng_c, n), masks))

            self._chunk_cache[key] = chunk_fn
        return self._chunk_cache[key]

    # -- asynchronous regime -------------------------------------------------

    def _job_fn(self, engine: Engine, kk: int, b: int):
        """k local steps for ONE client (no leading axis), jit per (k, b)."""
        key = ("job", kk, b)
        if key not in self._chunk_cache:
            cfg = engine.cfg
            wloss = self.wloss
            momentum, lr_alpha = cfg.momentum, self.lr_alpha
            batch_weights = make_batch_weights(b, self.grow,
                                               cfg.batch_per_client,
                                               cfg.max_batch)

            @jax.jit
            def job(params, mom, t, rng, data, center, eta):
                def step(c, r):
                    p, m, tt = c
                    eta_t = eta / (1.0 + lr_alpha * tt)
                    w = batch_weights(tt)
                    p2, m2 = client_sgd_step(wloss, b, momentum, p, m, data,
                                             r, center, w, eta_t)
                    return (p2, m2, tt + 1.0), None

                (params, mom, t), _ = jax.lax.scan(
                    step, (params, mom, t), jax.random.split(rng, kk))
                return params, mom, t

            self._chunk_cache[key] = job
        return self._chunk_cache[key]

    def _run_stage_async(self, stage, engine: Engine) -> StageStatus:
        """Barrier-free stage: budget = N·T_s local steps consumed greedily;
        the server merges each upload on arrival with staleness weights.
        Stage boundaries are the only barriers (η_s changes, prox re-centers,
        every client re-pulls the server model)."""
        red = self.merge_reducer
        status = StageStatus()
        hist_mark = len(self.history)
        tracer = self._tracer
        dropouts = self._metrics.counter(
            "runtime.dropout_events", unit="events",
            help="uploads lost / rounds missed to dropout")
        staleness_hist = self._metrics.histogram(
            "runtime.merge_staleness", unit="server cycles (normalized)",
            help="staleness weight input of async merges")
        s_active = self._vseries(
            "runtime.active_clients", "clients",
            "clients participating in the barrier round / holding work")
        s_inflight = self._vseries(
            "runtime.inflight_merges", "uploads",
            "async uploads in flight toward the server")
        s_stale = self._vseries(
            "runtime.merge_staleness", "server cycles (normalized)",
            "staleness weight input of each async merge")
        n_uploading = 0
        # stage-start barrier: everyone pulls the current server model
        for i in range(self.N):
            self._c_params[i] = self.server
        center = self.server if self.use_prox else None
        budget = self.N * stage.T
        inflight: dict = {}        # cid -> (kk, rng, pulled_version, ref | payload)
        stopping = False

        def dispatch(cid: int):
            nonlocal budget
            kk = min(stage.k, budget)
            if kk <= 0 or stopping:
                return
            budget -= kk
            self.rng, sub = jax.random.split(self.rng)
            c = self.clients[cid]
            inflight[cid] = (kk, sub, self.server_version,
                             self._c_params[cid])
            self.queue.push(self.clock.now + c.compute_time(kk),
                            "compute_done", cid)

        def record(now: float, v: float):
            self.history.append(Record(self.rounds_done, self.iters_done, v))
            self.timeline.append((now, self.rounds_done, v))

        for cid in range(self.N):
            dispatch(cid)

        while self.queue:
            ev = self.queue.pop()
            now = self.clock.advance(ev.time)
            self.trace.append((ev.time, ev.kind, ev.client))
            cid = ev.client
            c = self.clients[cid]
            if ev.kind == "compute_done":
                kk, sub, v_pull, ref = inflight.pop(cid)
                if tracer:
                    tracer.add("local_steps", now - c.compute_time(kk), now,
                               cat=CAT_COMPUTE, track=f"client/{cid}",
                               clock=VIRTUAL,
                               attrs={"steps": kk,
                                      "straggler": c.straggler})
                job = self._job_fn(engine, kk, self.batch)
                pre_mom, pre_t = self._c_mom[cid], self._c_t[cid]
                self._c_params[cid], self._c_mom[cid], self._c_t[cid] = job(
                    self._c_params[cid], self._c_mom[cid], self._c_t[cid],
                    sub, self._c_data[cid], center, stage.eta)
                self.iters_done += kk
                status.iters += kk
                if self.hetero.dropout > 0.0 \
                        and self._np.random_sample() < self.hetero.dropout:
                    # upload lost: the whole job is discarded — params back
                    # to the server pull, momentum and schedule index back
                    # to their pre-job values (the steps count as wasted
                    # compute in the ledger, not as optimizer progress)
                    self.trace.append((now, "drop", cid))
                    dropouts.inc(mode="async")
                    if tracer:
                        tracer.instant("drop", now, cat=CAT_CONTROL,
                                       track=f"client/{cid}", clock=VIRTUAL)
                    self._c_params[cid] = self.server
                    self._c_mom[cid], self._c_t[cid] = pre_mom, pre_t
                    dispatch(cid)
                    s_active.record(now, float(len(inflight)))
                    continue
                delta = jax.tree.map(
                    lambda p, r: p.astype(jnp.float32) - r.astype(jnp.float32),
                    self._c_params[cid], ref)
                payload, self._c_res[cid] = red.encode(
                    delta, self._c_res[cid],
                    jax.random.fold_in(sub, _COMM_SALT))
                inflight[cid] = (kk, v_pull, payload)
                self.queue.push(now + c.upload_time(self._msg_bytes),
                                "arrival", cid)
                n_uploading += 1
                s_inflight.record(now, float(n_uploading))
                s_active.record(now, float(len(inflight)))
            elif ev.kind == "arrival":
                kk, v_pull, payload = inflight.pop(cid)
                n_uploading -= 1
                s_inflight.record(now, float(n_uploading))
                # cycles beyond the natural pipeline lag: racing the other
                # N-1 clients' merges once is keeping pace, not staleness
                staleness = max(
                    0, self.server_version - v_pull - (self.N - 1)) / self.N
                if tracer:
                    tracer.add("reduce",
                               now - c.upload_time(self._msg_bytes), now,
                               cat=CAT_COMM, track=f"client/{cid}",
                               clock=VIRTUAL,
                               attrs={"bytes": self._msg_bytes})
                    tracer.instant("merge", now, cat=CAT_MERGE,
                                   track="server", clock=VIRTUAL,
                                   attrs={"client": cid,
                                          "staleness": staleness})
                staleness_hist.observe(staleness,
                                       reducer=red.name)
                s_stale.record(now, float(staleness))
                self.server = red.merge(self.server, payload, staleness,
                                        self.N)
                self.server_version += 1
                status.rounds += 1
                self.rounds_done += 1
                self._round_times.append(now)
                # target-hunting evaluates every merge (matching the sync
                # backend's per-round check); otherwise only the recorded
                # eval_every-th merges pay for an eval
                if not stopping and (self.target is not None
                                     or self.rounds_done
                                     % self.eval_every == 0):
                    v = float(self.eval_fn(self.server))
                    at_target = self.target is not None and v <= self.target
                    if at_target or self.rounds_done % self.eval_every == 0:
                        record(now, v)
                    if at_target:
                        stopping = True
                        status.stop = True
                if self.max_rounds is not None \
                        and self.rounds_done >= self.max_rounds:
                    stopping = True
                    status.stop = True
                self._c_params[cid] = self.server
                dispatch(cid)
                s_active.record(now, float(len(inflight)))

        # stage-end barrier: drain done above; record the closing objective
        v = float(self.eval_fn(self.server))
        if not self.history[hist_mark:] \
                or self.history[-1].round != self.rounds_done:
            record(self.clock.now, v)
        if self.target is not None and v <= self.target:
            status.stop = True
        # keep the stacked view coherent for finish()/cross-stage consumers
        self.params = tree_broadcast_leading(self.server, self.N)
        return status


@dataclass
class RuntimeResult:
    """What a discrete-event run produced, numerics and clock together."""

    history: List[Record]              # (round, iteration, objective) trace
    wall_clock_s: float                # modeled end-to-end wall time
    rounds: int
    iters: int
    comm_bytes: int                    # engine ledger (modeled payload bytes)
    comm_time_s: float                 # engine ledger (serial α–β link time)
    timeline: List[Tuple[float, int, float]]  # (time_s, round, objective)
    # full event log; per-leaf entries ("leaf_arrival", "leaf_broadcast",
    # "wan_leaf") carry the leaf index as a fourth element (see
    # clock.TraceEntry)
    trace: List[TraceEntry]
    params: Any = None                 # final consensus / server model
    # per-(leaf, hop) comm totals for the whole run (engine.leaf_ledger():
    # modeled payload bytes + serial α–β seconds per leaf); None when the
    # topology has no per-leaf accounting. Summing the entries reconciles
    # with comm_bytes (bit-exact) and comm_time_s (float-sum precision).
    leaf_ledger: Optional[List[dict]] = None


def run(loss_fn, init_params, client_data, cfg: TrainConfig, eval_fn, *,
        eval_every: int = 1, max_rounds: Optional[int] = None,
        target: Optional[float] = None, lr_alpha: float = 0.0,
        chunk_rounds: int = 32, reducer=None, topology=None,
        hetero: Optional[Heterogeneity] = None,
        schedule=None, tracer=None, series=None) -> RuntimeResult:
    """Run ``cfg.algo`` on the event runtime; the ``simulate.run`` of clocks.

    Same problem signature as ``core.simulate.run``. ``cfg.async_mode``
    (or an ``algo`` name carrying the ``+async`` suffix) switches to
    barrier-free merge-on-arrival rounds; the heterogeneity profile comes
    from the TrainConfig runtime fields unless ``hetero`` overrides it.
    ``cfg.upload_schedule`` (or the explicit ``schedule`` arg) picks how
    round-end uploads meet the clock — "blocking" monolithic messages or
    "streaming" per-leaf uploads overlapping the final local step.
    With heterogeneity disabled and a synchronous policy, ``.history`` is
    bit-exact with ``simulate.run`` — for *both* schedules: streaming
    changes modeled time only, never the trajectory.
    """
    algo = get_algorithm(cfg.algo)
    if cfg.async_mode:
        algo = make_async(algo)
    if algo.sync_policy.asynchronous:
        if topology is not None:
            raise ValueError(
                "asynchronous merging builds its own "
                "Star(StalenessWeightedMean); configure the messages via "
                "reducer=/cfg fields instead of passing topology=")
        if getattr(cfg, "topology", "star") not in (None, "star", "flat"):
            raise ValueError(
                "asynchronous merging is a flat star protocol; "
                f"topology={cfg.topology!r} only composes with barrier rounds")
        if getattr(cfg, "count_downlink", False):
            raise ValueError(
                "count_downlink prices the per-round consensus broadcast; "
                "asynchronous merging has no broadcast (clients pull on "
                "dispatch) — it composes with barrier rounds only")
        merge_red = staleness_reducer_for(cfg, reducer)
        net = NetworkModel(latency_s=cfg.comm_latency_s,
                           bandwidth_gbps=cfg.comm_bandwidth_gbps)
        engine = Engine(algo, cfg, topology=Star(reducer=merge_red,
                                                 network=net),
                        tracer=tracer, series=series)
    else:
        engine = Engine(algo, cfg, topology=topology, reducer=reducer,
                        tracer=tracer, series=series)
    backend = EventBackend(loss_fn, init_params, client_data, eval_fn,
                           hetero=hetero, schedule=schedule,
                           eval_every=eval_every,
                           max_rounds=max_rounds, target=target,
                           lr_alpha=lr_alpha, chunk_rounds=chunk_rounds)
    history = engine.run(backend)
    log.debug("runtime_done", wall_clock_s=backend.clock.now,
              rounds=engine.report.rounds_total,
              iters=engine.report.iters_total,
              comm_bytes=engine.report.comm_bytes_total,
              asynchronous=backend.asynchronous)
    final = (backend.server if backend.asynchronous
             else tree_mean_leading(backend.params))
    return RuntimeResult(
        history=history, wall_clock_s=backend.clock.now,
        rounds=engine.report.rounds_total, iters=engine.report.iters_total,
        comm_bytes=engine.report.comm_bytes_total,
        comm_time_s=engine.report.comm_time_s,
        timeline=backend.timeline, trace=backend.trace, params=final,
        leaf_ledger=engine.leaf_ledger() or None)
