"""Upload schedules — how one client's round-end message meets the clock.

The event runtime prices every executed barrier round by replaying it as
client events. The *upload schedule* decides what those events are:

  BlockingSchedule    the historical model: the client finishes all k local
                      steps, then ships one monolithic message —
                      ``arrival = compute_done + α + total_bytes/bandwidth``.

  StreamingSchedule   per-leaf streaming reduce (the ROADMAP's
                      communication/compute overlap): leaf l's round delta
                      is final as soon as the *last local step* updates
                      leaf l, and backprop releases leaves in
                      reverse-layer order spread across that final step —
                      so leaf uploads start *before* ``compute_done`` and
                      overlap the remaining layers' compute. The uplink is
                      one serial streamed connection: the per-message
                      latency α is paid once when the stream opens, then
                      each leaf serializes at β as soon as it is released
                      and the link is free.

Numerics are untouched either way — the schedule is pure clock accounting
on top of the bit-exact synchronous replay, which is exactly why streaming
and blocking runs of the same config produce identical parameters while
their modeled wall-clocks differ. Units throughout: times in modeled
seconds, payloads in bytes, compute in local steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.runtime.client import ClientProcess

# (time_s, event kind, info tuple) — info carries the leaf index for
# per-leaf arrivals so traces stay attributable
ScheduledEvent = Tuple[float, str, tuple]


@dataclass(frozen=True)
class UploadSchedule:
    """Base protocol: turn one client's barrier round into clock events.

    ``round_events`` returns ``(events, finish_s)`` where ``events`` is the
    client's event list for the round — each ``(time_s, kind, info)`` —
    and ``finish_s`` (modeled seconds) is when the client's full message
    has arrived at the server; the barrier merges at the max finish over
    clients. ``leaf_bytes[i]`` is leaf i's compressed payload in bytes,
    ``leaf_fracs[i]`` its share of one local step's compute (unitless,
    sums to 1 — proportional to parameter count). ``active=False`` replays
    a dropped client: it missed its compute window but still answers the
    barrier with its zero-delta message.
    """

    name = "base"

    def round_events(self, client: ClientProcess, start: float, k_steps: int,
                     leaf_bytes: Sequence[int], leaf_fracs: Sequence[float],
                     active: bool = True
                     ) -> Tuple[List[ScheduledEvent], float]:
        raise NotImplementedError


@dataclass(frozen=True)
class BlockingSchedule(UploadSchedule):
    """One monolithic upload after all local compute — the historical
    round price ``k·step_time + α + Σ bytes / bandwidth`` per client."""

    name = "blocking"

    def round_events(self, client, start, k_steps, leaf_bytes, leaf_fracs,
                     active=True):
        total = sum(leaf_bytes)
        if not active:
            # upload-only zero-delta answer (missed the compute window)
            t = start + client.upload_time(total)
            return [(t, "arrival", ())], t
        done = start + client.compute_time(k_steps)
        t = done + client.upload_time(total)
        return [(done, "compute_done", ()), (t, "arrival", ())], t


@dataclass(frozen=True)
class StreamingSchedule(UploadSchedule):
    """Per-leaf streaming uploads overlapping the final local step.

    Release model: the final local step spans
    ``[done − step_time, done]``; its backward pass completes leaves in
    reverse-layer order, leaf l becoming final once its share of the
    step's compute (``leaf_fracs``, ∝ parameter count) has accumulated.
    Link model: one streamed connection — α once at stream open, then
    strictly serial ``bytes/bandwidth`` per leaf in release order; a leaf
    released while the link is busy queues. Emits one ``leaf_arrival``
    per leaf (info = (leaf index,)) plus the usual ``compute_done``;
    the client's finish is the last leaf's arrival, which is what lets a
    multi-leaf model hide most of its upload behind its own compute.
    """

    name = "streaming"

    def round_events(self, client, start, k_steps, leaf_bytes, leaf_fracs,
                     active=True):
        net = client.network
        order = list(range(len(leaf_bytes)))[::-1]  # reverse-layer release
        events: List[ScheduledEvent] = []
        if not active:
            # zero-delta answer: every leaf is "ready" at round start;
            # the stream just serializes them back-to-back
            t = start + net.latency_s
            for leaf in order:
                t += leaf_bytes[leaf] / net.bandwidth_Bps
                events.append((t, "leaf_arrival", (leaf,)))
            return events, t
        done = start + client.compute_time(k_steps)
        step = client.compute_time(1)
        t_back = done - step            # final step begins
        events.append((done, "compute_done", ()))
        cum = 0.0
        link_free = None
        finish = done
        for leaf in order:
            cum += leaf_fracs[leaf]
            ready = t_back + step * cum
            if link_free is None:
                link_free = ready + net.latency_s  # stream opens once
            send = max(ready, link_free)
            finish = send + leaf_bytes[leaf] / net.bandwidth_Bps
            link_free = finish
            events.append((finish, "leaf_arrival", (leaf,)))
        return events, finish


def get_schedule(spec) -> UploadSchedule:
    """Resolve an upload schedule from a config string (or pass through).

    Accepted specs: "blocking" (default) | "streaming" / "stream".
    """
    if isinstance(spec, UploadSchedule):
        return spec
    if spec in (None, "blocking", "block"):
        return BlockingSchedule()
    if spec in ("streaming", "stream"):
        return StreamingSchedule()
    raise ValueError(f"unknown upload schedule spec: {spec!r}")
