"""Upload schedules — how one client's round-end message meets the clock.

The event runtime prices every executed barrier round by replaying it as
client events. The *upload schedule* decides what those events are:

  BlockingSchedule    the historical model: the client finishes all k local
                      steps, then ships one monolithic message —
                      ``arrival = compute_done + α + total_bytes/bandwidth``.

  StreamingSchedule   per-leaf streaming reduce (the ROADMAP's
                      communication/compute overlap): leaf l's round delta
                      is final as soon as the *last local step* updates
                      leaf l, and backprop releases leaves in
                      reverse-layer order spread across that final step —
                      so leaf uploads start *before* ``compute_done`` and
                      overlap the remaining layers' compute. The uplink is
                      one serial streamed connection: the per-message
                      latency α is paid once when the stream opens, then
                      each leaf serializes at β as soon as it is released
                      and the link is free.

Both schedules also price the *downlink* (``broadcast_events``) when the
client link bills it (``NetworkModel.count_downlink``): blocking ships the
consensus as one monolithic broadcast after the whole round has merged;
streaming ships leaf l's broadcast as soon as the server finishes reducing
leaf l — high-index leaves (reduced first under the reverse-order uplink)
serialize down while the server is still merging the early layers, so the
next round starts ``≈ α + first_leaf_bytes/β`` after the final merge
instead of a full model transfer later.

Numerics are untouched either way — the schedule is pure clock accounting
on top of the bit-exact synchronous replay, which is exactly why streaming
and blocking runs of the same config produce identical parameters while
their modeled wall-clocks differ. Units throughout: times in modeled
seconds, payloads in bytes, compute in local steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.runtime.client import ClientProcess

# (time_s, event kind, info tuple) — info carries the leaf index for
# per-leaf arrivals so traces stay attributable
ScheduledEvent = Tuple[float, str, tuple]


@dataclass(frozen=True)
class UploadSchedule:
    """Base protocol: turn one client's barrier round into clock events.

    ``round_events`` returns ``(events, finish_s)`` where ``events`` is the
    client's event list for the round — each ``(time_s, kind, info)`` —
    and ``finish_s`` (modeled seconds) is when the client's full message
    has arrived at the server; the barrier merges at the max finish over
    clients. ``leaf_bytes[i]`` is leaf i's compressed payload in bytes,
    ``leaf_fracs[i]`` its share of one local step's compute (unitless,
    sums to 1 — proportional to parameter count). ``active=False`` replays
    a dropped client: it missed its compute window but still answers the
    barrier with its zero-delta message.
    """

    name = "base"
    # capability flags the event runtime branches on: does the schedule
    # stream the uplink per leaf, and does it stream the *whole* round
    # (per-leaf WAN hop + per-leaf downlink) rather than the uplink only?
    streams_uplink = False
    streams_round = False

    def round_events(self, client: ClientProcess, start: float, k_steps: int,
                     leaf_bytes: Sequence[int], leaf_fracs: Sequence[float],
                     active: bool = True
                     ) -> Tuple[List[ScheduledEvent], float]:
        raise NotImplementedError

    def broadcast_events(self, client: ClientProcess,
                         leaf_done: Sequence[float],
                         leaf_bytes: Sequence[int]
                         ) -> Tuple[List[ScheduledEvent], float]:
        """Price the server→client downlink of one round.

        ``leaf_done[l]`` is the modeled time the server finished reducing
        leaf l (all equal to the merge instant under a blocking barrier);
        ``leaf_bytes[l]`` is leaf l's *dense* broadcast payload (the
        downlink ships the uncompressed consensus — cost_model.md).
        Returns ``(events, ready_s)``: ``ready_s`` is when the client
        holds the full consensus and can begin the next round's local
        compute. On links that don't bill the downlink
        (``count_downlink=False``) this is free: no events, ready at the
        final merge.
        """
        raise NotImplementedError


@dataclass(frozen=True)
class BlockingSchedule(UploadSchedule):
    """One monolithic upload after all local compute — the historical
    round price ``k·step_time + α + Σ bytes / bandwidth`` per client."""

    name = "blocking"

    def round_events(self, client, start, k_steps, leaf_bytes, leaf_fracs,
                     active=True):
        total = sum(leaf_bytes)
        if not active:
            # upload-only zero-delta answer (missed the compute window)
            t = start + client.upload_time(total)
            return [(t, "arrival", ())], t
        done = start + client.compute_time(k_steps)
        t = done + client.upload_time(total)
        return [(done, "compute_done", ()), (t, "arrival", ())], t

    def broadcast_events(self, client, leaf_done, leaf_bytes):
        net = client.network
        merged = max(leaf_done)
        if not net.count_downlink:
            return [], merged
        # one monolithic broadcast after the whole round has merged
        t = merged + net.latency_s + sum(leaf_bytes) / net.bandwidth_Bps
        return [(t, "broadcast_arrival", ())], t


@dataclass(frozen=True)
class StreamingSchedule(UploadSchedule):
    """Per-leaf streaming uploads overlapping the final local step.

    Release model: the final local step spans
    ``[done − step_time, done]``; its backward pass completes leaves in
    reverse-layer order, leaf l becoming final once its share of the
    step's compute (``leaf_fracs``, ∝ parameter count) has accumulated.
    Link model: one streamed connection — α once at stream open, then
    strictly serial ``bytes/bandwidth`` per leaf in release order; a leaf
    released while the link is busy queues. Emits one ``leaf_arrival``
    per leaf (info = (leaf index,)) plus the usual ``compute_done``;
    the client's finish is the last leaf's arrival, which is what lets a
    multi-leaf model hide most of its upload behind its own compute.

    By default the *whole round* streams: the downlink broadcast (and,
    under a hierarchical topology, the inter-pod WAN hop — see
    ``EventBackend``) also run per leaf in server-completion order.
    ``uplink_only=True`` restores the PR-4 comparator semantics — per-leaf
    uplink, but a blocking WAN hop and monolithic broadcast — which is the
    baseline the streaming∘hierarchical benchmark rows beat.
    """

    uplink_only: bool = False

    streams_uplink = True

    @property
    def name(self):
        return "streaming-uplink" if self.uplink_only else "streaming"

    @property
    def streams_round(self):
        return not self.uplink_only

    def round_events(self, client, start, k_steps, leaf_bytes, leaf_fracs,
                     active=True):
        net = client.network
        order = list(range(len(leaf_bytes)))[::-1]  # reverse-layer release
        events: List[ScheduledEvent] = []
        if not active:
            # zero-delta answer: every leaf is "ready" at round start;
            # the stream just serializes them back-to-back
            t = start + net.latency_s
            for leaf in order:
                t += leaf_bytes[leaf] / net.bandwidth_Bps
                events.append((t, "leaf_arrival", (leaf,)))
            return events, t
        done = start + client.compute_time(k_steps)
        step = client.compute_time(1)
        t_back = done - step            # final step begins
        events.append((done, "compute_done", ()))
        cum = 0.0
        link_free = None
        finish = done
        for leaf in order:
            cum += leaf_fracs[leaf]
            ready = t_back + step * cum
            if link_free is None:
                link_free = ready + net.latency_s  # stream opens once
            send = max(ready, link_free)
            finish = send + leaf_bytes[leaf] / net.bandwidth_Bps
            link_free = finish
            events.append((finish, "leaf_arrival", (leaf,)))
        return events, finish

    def broadcast_events(self, client, leaf_done, leaf_bytes):
        net = client.network
        merged = max(leaf_done)
        if not net.count_downlink:
            return [], merged
        if self.uplink_only:
            # PR-4 comparator: monolithic broadcast after the merge
            t = merged + net.latency_s + sum(leaf_bytes) / net.bandwidth_Bps
            return [(t, "broadcast_arrival", ())], t
        # streamed downlink: leaf l ships as soon as the server finishes
        # reducing it. Completion order is reverse-leaf order (the uplink
        # streams leaves back-to-front), so high-index leaves serialize
        # down while the early layers are still merging and the round's
        # last landing — leaf 0, the first the next forward pass needs —
        # trails the final merge by only α (amortized) + its own
        # serialization instead of the full model's.
        events: List[ScheduledEvent] = []
        link_free = None
        fin = merged
        for leaf in range(len(leaf_bytes) - 1, -1, -1):
            ready = leaf_done[leaf]
            if link_free is None:
                link_free = ready + net.latency_s  # stream opens once
            send = max(ready, link_free)
            fin = send + leaf_bytes[leaf] / net.bandwidth_Bps
            link_free = fin
            events.append((fin, "leaf_broadcast", (leaf,)))
        return events, fin


def get_schedule(spec) -> UploadSchedule:
    """Resolve an upload schedule from a config string (or pass through).

    Accepted specs: "blocking" (default) | "streaming" / "stream" |
    "streaming-uplink" (per-leaf uplink only: blocking WAN hop + monolithic
    broadcast — the PR-4 comparator).
    """
    if isinstance(spec, UploadSchedule):
        return spec
    if spec in (None, "blocking", "block"):
        return BlockingSchedule()
    if spec in ("streaming", "stream"):
        return StreamingSchedule()
    if spec in ("streaming-uplink", "stream-uplink", "uplink"):
        return StreamingSchedule(uplink_only=True)
    raise ValueError(f"unknown upload schedule spec: {spec!r}")
