"""repro.serve — continuous-batching inference over STL-SGD checkpoints.

The serving half of the repro stack: restore a ``launch/train.py
--ckpt-out`` checkpoint, put it behind admission control and a fixed
KV-cache slot pool, and drive it with open-loop synthetic traffic on the
discrete-event virtual clock. Layers:

  * ``traffic``   — Poisson / bursty (MMPP) arrival processes, sampled
    prompt/output lengths; pure function of seed.
  * ``scheduler`` — bounded-queue FCFS admission control, token budget,
    prefill/decode interleaving cap, lowest-index slot allocation.
  * ``engine``    — ``ServeEngine``: jitted prefill + vmapped decode with
    donated cache buffers; requests join/retire at step boundaries
    without draining the batch. Bit-exact per slot with
    ``core.serving.greedy_decode``.
  * ``ledger``    — per-request latency records (queue wait, TTFT, TPOT,
    e2e) surfaced as ``request > {queue, prefill, decode}`` spans and
    ``serve.*`` metrics with p50/p95/p99 summaries.

See docs/serving.md for the request lifecycle and the latency taxonomy;
``benchmarks/table6_serving.py`` sweeps offered load → throughput/latency.
"""
from repro.serve.engine import DeviceModel, ServeEngine, ServeReport
from repro.serve.ledger import RequestRecord, emit_spans, publish_metrics
from repro.serve.scheduler import (
    Admission,
    Scheduler,
    SchedulerConfig,
    SlotPool,
)
from repro.serve.traffic import (
    Request,
    TrafficConfig,
    arrival_summary,
    generate_requests,
    offered_load,
)

__all__ = [
    "DeviceModel", "ServeEngine", "ServeReport",
    "RequestRecord", "emit_spans", "publish_metrics",
    "Admission", "Scheduler", "SchedulerConfig", "SlotPool",
    "Request", "TrafficConfig", "arrival_summary", "generate_requests",
    "offered_load",
]
