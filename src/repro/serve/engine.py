"""Continuous-batching inference engine over a fixed KV-cache slot pool.

``ServeEngine`` closes the train → checkpoint → serve loop: it restores
params via ``checkpoint/ckpt.py`` (``from_checkpoint``), builds jitted
prefill/decode steps from ``core/serving.py``, and runs an event-driven
decode loop in which requests join free slots at step boundaries and
finished sequences retire without draining the batch.

Execution model
---------------
The decode batch is always ``n_slots`` wide: one *slot* = one independent
single-sequence KV cache (batch dim 1) with its own position counter. The
decode step is ``jit(vmap(decode_step))`` over the slot axis with the
stacked cache **donated** (palivla's sjit/``donate_argnums`` step
construction) — the cache is updated in place across steps instead of
copied. Because each slot's lanes are independent under vmap, a slot's
token stream is bit-exact with the per-request ``greedy_decode`` reference
regardless of arrival order and slot assignment — the batching-invariance
property ``tests/test_serve.py`` pins (tokens *and* raw logits).

Two timelines
-------------
Time is *modeled* on the ``runtime.clock`` virtual clock: arrivals come
from ``traffic.offered_load``, prefills and decode steps advance the clock
by roofline-priced costs (``launch/flops.py`` compute/HBM terms +
``comm.NetworkModel`` α–β activation-collective term when the modeled mesh
has >1 chip). Same traffic seed ⇒ identical event order, latency ledger
and span tree. Host wall time is measured alongside (never fed back into
scheduling), so reports show modeled and measured throughput side by side.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint
from repro.comm.cost import NetworkModel, link_model
from repro.configs import get_arch
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.serving import build_prefill_step, build_serve_step
from repro.launch.flops import shape_flops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from repro.models import transformer as TF
from repro.obs import CAT_COMPUTE, CAT_CONTROL, VIRTUAL
from repro.obs import metrics as obs_metrics
from repro.obs import series as obs_series
from repro.runtime.clock import Clock
from repro.serve import ledger as serve_ledger
from repro.serve.ledger import RequestRecord
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.traffic import Request, offered_load
from repro.utils.logging import get_logger

log = get_logger("serve.engine")


@dataclass(frozen=True)
class DeviceModel:
    """Hardware model pricing one serve step in modeled seconds.

    Roofline: ``max(step_flops / (n_chips × peak), hbm_bytes / (n_chips ×
    bw))``. With ``n_chips > 1`` the modeled mesh shards the step, and
    every step additionally pays one α–β activation all-reduce on ``link``
    (≈ ``2 × tokens × d_model`` bf16 bytes per layer — the ring-collective
    payload that model-sharded decode cannot hide).
    Defaults are the v5e constants from ``launch/mesh.py``.
    """

    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    n_chips: int = 1
    link: Optional[NetworkModel] = None    # default: calibrated ICI

    def _link(self) -> NetworkModel:
        return self.link if self.link is not None else link_model("ici")

    def step_time_s(self, cfg: ArchConfig, shape: ShapeConfig) -> float:
        fr = shape_flops(cfg, shape)
        t = max(fr.step_flops / (self.n_chips * self.peak_flops),
                fr.hbm_bytes / (self.n_chips * self.hbm_bw))
        if self.n_chips > 1:
            tokens = shape.global_batch * (1 if shape.mode == "decode"
                                           else shape.seq_len)
            coll = 2.0 * tokens * cfg.d_model * 2.0 * cfg.n_layers
            t += self._link().time(coll)
        return t


@dataclass
class ServeReport:
    """Everything one ``ServeEngine.run`` produced.

    ``records`` cover every offered request (completed and rejected, id
    order); modeled numbers are deterministic per seed, ``measured_*``
    are host wall-clock and vary run to run.
    """

    records: List[RequestRecord]
    n_steps: int                     # executed decode steps
    n_prefills: int
    makespan_s: float                # modeled: virtual clock at drain
    decode_step_s: float             # modeled price of one decode step
    mean_occupancy: float            # active slots averaged over steps
    modeled_tok_s: float             # generated tokens / modeled makespan
    measured_wall_s: float
    measured_tok_s: float
    registry: obs_metrics.MetricsRegistry = field(repr=False, default=None)
    series: obs_series.SeriesRegistry = field(repr=False, default=None)

    @property
    def completed(self) -> List[RequestRecord]:
        return [r for r in self.records if r.outcome == "completed"]

    @property
    def rejected(self) -> List[RequestRecord]:
        return [r for r in self.records if r.outcome != "completed"]

    def latency_summary(self) -> Dict[str, dict]:
        """p50/p95/p99 (+count/mean) per latency family, straight from the
        ``serve.*`` obs histograms this run published."""
        out = {}
        for name in ("serve.queue_wait_s", "serve.ttft_s", "serve.tpot_s",
                     "serve.e2e_s"):
            if name in self.registry:
                s = self.registry[name].summary()
                if s is not None:
                    out[name] = s
        return out

    def trace_keys(self) -> list:
        """Deterministic fingerprint of the whole ledger (determinism
        tests compare these across same-seed runs)."""
        return [r.trace_key() for r in self.records]


@dataclass
class _SlotState:
    """Host-side view of one occupied slot."""

    record: RequestRecord
    generated: int                   # tokens produced so far (>= 1)


class ServeEngine:
    """Continuous-batching serving driver (see module docstring)."""

    def __init__(self, cfg: ArchConfig, params, *,
                 scheduler: Optional[SchedulerConfig] = None,
                 device: Optional[DeviceModel] = None):
        self.cfg = cfg
        self.params = params
        self.sched_cfg = scheduler or SchedulerConfig()
        self.device = device or DeviceModel()
        self.max_seq_len = self.sched_cfg.max_seq_len
        self.n_slots = self.sched_cfg.n_slots

        prefill_step = build_prefill_step(cfg)
        serve_step = build_serve_step(cfg)

        def _prefill(params, cache, prompt, frontend=None):
            logits, cache = prefill_step(params, cache, prompt, frontend)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return tok, cache

        def _decode(params, toks, stacked):
            def one(tok, cache):
                logits, cache = serve_step(params, cache, tok)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

            return jax.vmap(one)(toks, stacked)

        def _join(stacked, toks, cache, tok, slot):
            stacked = jax.tree.map(
                lambda buf, x: jax.lax.dynamic_update_index_in_dim(
                    buf, x, slot, 0), stacked, cache)
            return stacked, jax.lax.dynamic_update_index_in_dim(
                toks, tok, slot, 0)

        # donated buffers: the stacked cache (and token front) are threaded
        # through jit in place — zero-copy across decode steps
        self._prefill = jax.jit(_prefill, donate_argnums=(1,))
        self._decode = jax.jit(_decode, donate_argnums=(1, 2))
        self._join = jax.jit(_join, donate_argnums=(0, 1))

        # modeled price of one (always full-width) decode step
        self.decode_step_s = self.device.step_time_s(
            cfg, ShapeConfig("serve_decode", self.max_seq_len,
                             self.n_slots, "decode"))

    # -- construction -------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, directory: str, step: Optional[int] = None,
                        **kwargs) -> "ServeEngine":
        """Restore a ``launch/train.py --ckpt-out`` artifact and serve it.

        The template load needs an arch before it can build shapes, so the
        restore is two-phase: peek at the npz's ``__meta__`` for the arch
        name, rebuild the params template from the registry, then do the
        real shape/dtype-checked load.
        """
        import json
        import os

        from repro.checkpoint.ckpt import latest_step

        s = step if step is not None else latest_step(directory)
        if s is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        path = os.path.join(directory, f"step_{s:010d}.npz")
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
        if "arch" not in meta:
            raise ValueError(
                f"{path}: checkpoint meta has no 'arch' key — was it "
                "written by launch/train.py --ckpt-out?")
        cfg = get_arch(meta["arch"], smoke=bool(meta.get("smoke", False)))
        template = TF.init_params_shape(cfg)
        params, meta = load_checkpoint(directory, template, step=s)
        params = jax.tree.map(jnp.asarray, params)
        log.info("restored %s step=%d (algo=%s rounds=%s)", meta["arch"], s,
                 meta.get("algo"), meta.get("rounds"))
        return cls(cfg, params, **kwargs)

    # -- pricing ------------------------------------------------------------

    def prefill_s(self, req: Request) -> float:
        """Modeled cost of one request's prefill (frontend tokens count)."""
        fe = self.cfg.n_frontend_tokens if req.frontend is not None else 0
        return self.device.step_time_s(
            self.cfg, ShapeConfig("serve_prefill", req.prompt_len + fe, 1,
                                  "prefill"))

    # -- the loop -----------------------------------------------------------

    def run(self, requests: List[Request], tracer=None,
            registry: Optional[obs_metrics.MetricsRegistry] = None,
            series: Optional[obs_series.SeriesRegistry] = None,
            profile=None) -> ServeReport:
        """Serve ``requests`` (open loop) until the system drains.

        ``series`` (default: the process registry) receives the live
        virtual-clock telemetry — ``serve.queue_depth`` /
        ``serve.batch_occupancy`` per decode step, the cumulative
        ``serve.tokens_total`` (plus its derived ``serve.tokens_s`` rate)
        and the per-request latency sample series. ``profile`` (an
        ``obs.ProfileSession``) wall-times every jitted prefill/decode
        call against its modeled price for the skew table.
        """
        registry = registry or obs_metrics.registry()
        series = series if series is not None else obs_series.registry()
        s_queue = series.series(
            "serve.queue_depth", clock=VIRTUAL, unit="requests",
            help="waiting requests at each decode-step boundary")
        s_occ = series.series(
            "serve.batch_occupancy", clock=VIRTUAL, unit="slots",
            help="active slots in each decode step")
        s_tok = series.series(
            "serve.tokens_total", clock=VIRTUAL, unit="tokens",
            help="cumulative generated tokens (prefill + decode)")
        events = offered_load(requests)
        by_id = {r.id: r for r in requests}
        clock = Clock()
        sched = Scheduler(self.sched_cfg,
                          n_frontend_tokens=self.cfg.n_frontend_tokens)
        slots: List[Optional[_SlotState]] = [None] * self.n_slots
        records: Dict[int, RequestRecord] = {}

        one = TF.init_cache(self.cfg, 1, self.max_seq_len)
        stacked = jax.tree.map(
            lambda v: jnp.stack([v] * self.n_slots), one)
        toks = jnp.zeros((self.n_slots, 1, 1), jnp.int32)

        n_steps = n_prefills = 0
        occupancy_sum = 0
        tokens_out = 0
        gen_total = 0
        run_span = tracer.span("serve_run", track="server", attrs={
            "n_requests": len(requests), "n_slots": self.n_slots}) \
            if tracer else None
        if run_span:
            run_span.__enter__()
        t_wall0 = time.monotonic()

        def _offer(req: Request):
            rec = RequestRecord(id=req.id, prompt_len=req.prompt_len,
                                n_out=req.n_out, arrival_s=req.arrival_s)
            records[req.id] = rec
            if not sched.offer(req):
                too_long = any(r is req for r in sched.rejected_too_long)
                rec.outcome = ("rejected_too_long" if too_long
                               else "rejected_full")

        def _retire(slot: int, t: float):
            nonlocal tokens_out
            st = slots[slot]
            st.record.finish_s = t
            tokens_out += st.record.n_out
            sched.release(slot)
            slots[slot] = None

        while events or not sched.idle:
            # 1. arrivals due now enter admission control
            while events and events.peek().time <= clock.now:
                _offer(by_id[events.pop().client])
            # 2. idle system: jump to the next arrival
            if sched.idle:
                if not events:
                    break
                clock.advance(events.peek().time)
                continue
            # 3. step boundary: admissions join free slots (serialized
            #    prefills, capped by the interleaving policy)
            for adm in sched.admit():
                req, slot = adm.request, adm.slot
                rec = records[req.id]
                rec.slot, rec.admit_s = slot, clock.now
                fresh = TF.init_cache(self.cfg, 1, self.max_seq_len)
                prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
                fe = (jnp.asarray(req.frontend[None], jnp.bfloat16)
                      if req.frontend is not None else None)
                p_args = ((self.params, fresh, prompt, fe)
                          if fe is not None
                          else (self.params, fresh, prompt))
                if profile is not None:
                    tok1, cache1 = profile.step(
                        "serve.prefill", self.prefill_s(req),
                        self._prefill, *p_args)
                else:
                    tok1, cache1 = self._prefill(*p_args)
                stacked, toks = self._join(
                    stacked, toks, cache1, tok1, slot)
                n_prefills += 1
                clock.advance(clock.now + self.prefill_s(req))
                rec.first_token_s = clock.now
                rec.tokens.append(int(jax.device_get(tok1)[0, 0]))
                rec.token_times_s.append(clock.now)
                gen_total += 1
                s_tok.record(clock.now, float(gen_total))
                slots[slot] = _SlotState(record=rec, generated=1)
                if rec.n_out == 1:
                    _retire(slot, clock.now)
            # 4. one decode step over the full slot pool
            active = [i for i, st in enumerate(slots) if st is not None]
            if active:
                t0 = clock.now
                s_queue.record(t0, float(sched.queue_depth))
                s_occ.record(t0, float(len(active)))
                if profile is not None:
                    toks, stacked = profile.step(
                        "serve.decode_step", self.decode_step_s,
                        self._decode, self.params, toks, stacked)
                else:
                    toks, stacked = self._decode(self.params, toks, stacked)
                clock.advance(clock.now + self.decode_step_s)
                n_steps += 1
                occupancy_sum += len(active)
                gen_total += len(active)
                s_tok.record(clock.now, float(gen_total))
                host_toks = np.asarray(jax.device_get(toks))
                for i in active:
                    st = slots[i]
                    st.generated += 1
                    st.record.tokens.append(int(host_toks[i, 0, 0]))
                    st.record.token_times_s.append(clock.now)
                    if st.generated >= st.record.n_out:
                        _retire(i, clock.now)
                if tracer:
                    tracer.add("decode_step", t0, clock.now,
                               cat=CAT_COMPUTE, track="server",
                               clock=VIRTUAL,
                               attrs={"active": len(active),
                                      "queued": sched.queue_depth})

        measured_wall_s = time.monotonic() - t_wall0
        if run_span:
            run_span.set(n_steps=n_steps, n_prefills=n_prefills)
            run_span.__exit__(None, None, None)

        recs = [records[r.id] for r in sorted(requests, key=lambda r: r.id)]
        serve_ledger.emit_spans(tracer, recs)
        serve_ledger.publish_metrics(registry, recs)
        serve_ledger.publish_series(series, recs)
        if len(s_tok):
            # windowed throughput over ~64 decode steps of virtual time
            series.add(s_tok.rate(64.0 * self.decode_step_s,
                                  name="serve.tokens_s"))
        makespan = clock.now
        mean_occ = occupancy_sum / n_steps if n_steps else 0.0
        g = registry.gauge
        g("serve.occupancy", unit="slots",
          help="mean active slots per decode step").set(mean_occ)
        g("serve.queue_depth", unit="requests",
          help="waiting requests at drain").set(sched.queue_depth)
        modeled_tok_s = tokens_out / makespan if makespan > 0 else 0.0
        g("serve.modeled_tok_s", unit="tokens/s",
          help="generated tokens over modeled makespan").set(modeled_tok_s)
        measured_tok_s = (tokens_out / measured_wall_s
                          if measured_wall_s > 0 else 0.0)
        g("serve.measured_tok_s", unit="tokens/s",
          help="generated tokens over host wall time").set(measured_tok_s)
        return ServeReport(
            records=recs, n_steps=n_steps, n_prefills=n_prefills,
            makespan_s=makespan, decode_step_s=self.decode_step_s,
            mean_occupancy=mean_occ, modeled_tok_s=modeled_tok_s,
            measured_wall_s=measured_wall_s, measured_tok_s=measured_tok_s,
            registry=registry, series=series)
