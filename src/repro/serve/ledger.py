"""Per-request latency ledger — the serving analogue of the comm ledger.

Every request that enters the engine leaves one ``RequestRecord`` carrying
its full lifecycle on the virtual clock: arrival → (queue wait) → admit →
(prefill) → first token → (decode) → finish. Derived latencies follow the
standard serving taxonomy:

  queue_wait  admit − arrival          (admission control delay)
  TTFT        first_token − arrival    (time to first token, queue incl.)
  TPOT        decode / (n_out − 1)     (per-output-token decode time)
  e2e         finish − arrival

The ledger is surfaced through ``repro.obs`` twice:

  * ``emit_spans`` lays one ``request`` span per record — children
    ``queue`` / ``prefill`` / ``decode`` — on the virtual clock
    (track ``req/<id>``), next to the engine's live ``decode_step``
    spans, so the Perfetto export shows request lifetimes against batch
    occupancy;
  * ``publish_metrics`` feeds the ``serve.*`` histograms/counters whose
    p50/p95/p99 summaries the latency tables read (see the metric table
    in docs/serving.md). The latency histograms pin a high sample cap
    (65536) so the table columns stay *exact* percentiles of the ledger
    even past the default reservoir threshold;
  * ``publish_series`` feeds the per-request latency *sample series*
    (``serve.ttft_s`` / ``serve.tpot_s`` / ``serve.e2e_s``, one sample at
    each request's completion time) that the sliding-window SLO monitor
    (``obs.slo``) evaluates.

Records hold modeled times only — deterministic per (traffic seed,
scheduler config); measured wall-clock lives in the engine report, never
in the ledger.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.obs import CAT_COMPUTE, CAT_CONTROL, VIRTUAL
from repro.obs.metrics import MetricsRegistry
from repro.obs.series import SeriesRegistry

# latency histograms keep raw samples up to this cap so the p50/p95/p99
# table columns stay exact percentiles of the ledger (never reservoir
# approximations) at any realistic smoke/quick/full request volume
LATENCY_SAMPLE_CAP = 65536


@dataclass
class RequestRecord:
    """One request's lifecycle on the virtual clock (modeled seconds)."""

    id: int
    prompt_len: int
    n_out: int
    arrival_s: float
    outcome: str = "completed"   # completed | rejected_full | rejected_too_long
    slot: int = -1
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    token_times_s: List[float] = field(default_factory=list)

    # -- derived latencies (None until the lifecycle point is reached) ------

    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.admit_s is None else self.admit_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        return (None if self.first_token_s is None
                else self.first_token_s - self.arrival_s)

    @property
    def decode_s(self) -> Optional[float]:
        if self.finish_s is None or self.first_token_s is None:
            return None
        return self.finish_s - self.first_token_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Per-output-token decode time (0 for single-token requests)."""
        d = self.decode_s
        if d is None:
            return None
        return d / (self.n_out - 1) if self.n_out > 1 else 0.0

    @property
    def e2e_s(self) -> Optional[float]:
        return (None if self.finish_s is None
                else self.finish_s - self.arrival_s)

    def trace_key(self) -> tuple:
        """Deterministic identity for same-seed ⇒ same-trace assertions:
        everything modeled, including the generated token ids."""
        return (self.id, self.outcome, self.slot, self.prompt_len,
                self.n_out, self.arrival_s, self.admit_s,
                self.first_token_s, self.finish_s, tuple(self.tokens),
                tuple(self.token_times_s))


def emit_spans(tracer, records: List[RequestRecord]):
    """Lay the ledger onto a Tracer as ``request > {queue, prefill,
    decode}`` spans (virtual clock, one ``req/<id>`` track per request).

    Emitted after the run, in request-id order, so the span tree is a pure
    function of the ledger — the export *is* the ledger, not a parallel
    approximation of it.
    """
    if not tracer:
        return
    for r in sorted(records, key=lambda r: r.id):
        track = f"req/{r.id:03d}"
        if r.finish_s is None:    # rejected: a zero-length marker
            tracer.instant("rejected", r.arrival_s, cat=CAT_CONTROL,
                           track=track, clock=VIRTUAL,
                           attrs={"request": r.id, "outcome": r.outcome})
            continue
        rid = tracer.begin("request", r.arrival_s, cat=CAT_CONTROL,
                           track=track, clock=VIRTUAL,
                           attrs={"request": r.id, "slot": r.slot,
                                  "prompt_len": r.prompt_len,
                                  "n_out": r.n_out})
        tracer.add("queue", r.arrival_s, r.admit_s, cat=CAT_CONTROL,
                   track=track, clock=VIRTUAL,
                   attrs={"request": r.id})
        tracer.add("prefill", r.admit_s, r.first_token_s, cat=CAT_COMPUTE,
                   track=track, clock=VIRTUAL,
                   attrs={"request": r.id, "tokens": r.prompt_len})
        tracer.add("decode", r.first_token_s, r.finish_s, cat=CAT_COMPUTE,
                   track=track, clock=VIRTUAL,
                   attrs={"request": r.id, "tokens": r.n_out - 1})
        tracer.end(rid, r.finish_s)


def publish_metrics(registry: MetricsRegistry, records: List[RequestRecord]):
    """Feed the ledger into the ``serve.*`` metric families.

    Histograms retain raw samples, so their p50/p95/p99 summaries (the
    latency-table columns) are exact percentiles of the ledger.
    """
    req = registry.counter("serve.requests", unit="requests",
                           help="requests by outcome")
    toks = registry.counter("serve.tokens_out", unit="tokens",
                            help="generated tokens over completed requests")
    hists = {
        "queue_wait_s": registry.histogram(
            "serve.queue_wait_s", unit="s",
            help="admission-control delay (admit - arrival)",
            cap=LATENCY_SAMPLE_CAP),
        "ttft_s": registry.histogram(
            "serve.ttft_s", unit="s",
            help="time to first token (queue wait + prefill)",
            cap=LATENCY_SAMPLE_CAP),
        "tpot_s": registry.histogram(
            "serve.tpot_s", unit="s",
            help="per-output-token decode time",
            cap=LATENCY_SAMPLE_CAP),
        "e2e_s": registry.histogram(
            "serve.e2e_s", unit="s", help="end-to-end request latency",
            cap=LATENCY_SAMPLE_CAP),
    }
    for r in records:
        req.inc(1, outcome=r.outcome)
        if r.outcome != "completed":
            continue
        toks.inc(r.n_out)
        for name, h in hists.items():
            v = getattr(r, name)
            if v is not None:
                h.observe(v)


def publish_series(series: SeriesRegistry, records: List[RequestRecord]):
    """Feed the ledger into per-request latency sample series.

    One sample per completed request on the virtual clock — TTFT at the
    moment the first token lands, TPOT/e2e at request finish — so the
    sliding-window SLO monitor (``obs.slo``) sees latencies in the order
    the serving system actually produced them. Samples arrive in request
    id order; the ``Series`` sorts by time lazily on read.
    """
    s_ttft = series.series("serve.ttft_s", clock=VIRTUAL, unit="s",
                           help="per-request time to first token")
    s_tpot = series.series("serve.tpot_s", clock=VIRTUAL, unit="s",
                           help="per-request per-output-token decode time")
    s_e2e = series.series("serve.e2e_s", clock=VIRTUAL, unit="s",
                          help="per-request end-to-end latency")
    for r in records:
        if r.outcome != "completed":
            continue
        if r.ttft_s is not None:
            s_ttft.record(r.first_token_s, r.ttft_s)
        if r.finish_s is not None:
            if r.tpot_s is not None:
                s_tpot.record(r.finish_s, r.tpot_s)
            if r.e2e_s is not None:
                s_e2e.record(r.finish_s, r.e2e_s)
