"""Admission control + slot allocation for the continuous-batching engine.

The engine owns a fixed pool of KV-cache slots (the decode batch is always
``n_slots`` wide; empty slots decode garbage that is never read). The
scheduler decides, at every decode-step boundary, which waiting requests
join free slots:

  * **bounded queue** — at most ``max_queue`` requests wait; arrivals past
    that are rejected (counted, never silently dropped);
  * **length guard** — a request whose prompt + generation (+ frontend
    tokens) cannot fit ``max_seq_len`` is rejected at enqueue time, not
    wedged forever at the head of the FCFS queue;
  * **token budget** — total cache-token footprint of in-flight requests
    is capped (defaults to ``n_slots × max_seq_len``, i.e. slot-bound);
  * **prefill/decode interleaving** — at most ``max_prefills_per_step``
    admissions per step boundary, so a deep queue cannot starve in-flight
    decodes (each admission costs one serialized prefill on the modeled
    clock).

Everything is deterministic: FCFS admission order, lowest-index-first slot
allocation, no wall-clock anywhere — two runs over the same traffic make
identical decisions, which the determinism tests pin.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serve.traffic import Request


class SlotPool:
    """Fixed pool of decode slots; lowest free index allocates first."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots))
        heapq.heapify(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Claim the lowest free slot index (raises when full)."""
        if not self._free:
            raise RuntimeError("slot pool exhausted")
        return heapq.heappop(self._free)

    def free(self, slot: int):
        """Return a slot to the pool."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range 0..{self.n_slots-1}")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        heapq.heappush(self._free, slot)


@dataclass(frozen=True)
class SchedulerConfig:
    """Admission-control knobs (see module docstring for semantics)."""

    n_slots: int = 8
    max_seq_len: int = 256           # per-slot KV-cache length (token slots)
    max_queue: int = 64              # bounded waiting room
    token_budget: Optional[int] = None   # in-flight cache tokens; None =
    #                                      n_slots × max_seq_len (slot-bound)
    max_prefills_per_step: int = 1   # admissions per decode-step boundary

    def resolved_budget(self) -> int:
        return (self.token_budget if self.token_budget is not None
                else self.n_slots * self.max_seq_len)


@dataclass
class Admission:
    """One admission decision: request → slot, at a step boundary."""

    request: Request
    slot: int


class Scheduler:
    """FCFS admission control over a bounded queue + the slot pool.

    Lifecycle per request: ``offer`` at arrival (may reject: queue full /
    too long), then ``admit`` at a step boundary moves the queue head into
    free slots subject to the token budget and the per-step prefill cap,
    then ``release`` at retirement frees the slot and its budget share.
    """

    def __init__(self, cfg: SchedulerConfig, n_frontend_tokens: int = 0):
        if cfg.max_prefills_per_step < 1:
            raise ValueError("max_prefills_per_step must be >= 1 "
                             f"(got {cfg.max_prefills_per_step})")
        self.cfg = cfg
        self.pool = SlotPool(cfg.n_slots)
        self.n_frontend_tokens = n_frontend_tokens
        self.queue: List[Request] = []       # FCFS waiting room
        self.in_flight: Dict[int, Request] = {}   # slot -> request
        self._budget_used = 0
        self.rejected_full: List[Request] = []
        self.rejected_too_long: List[Request] = []

    # -- accounting ---------------------------------------------------------

    def _footprint(self, req: Request) -> int:
        """Cache-token footprint: prompt + generated + frontend tokens."""
        fe = self.n_frontend_tokens if req.frontend is not None else 0
        return req.total_tokens + fe

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def occupancy(self) -> int:
        return len(self.in_flight)

    # -- lifecycle ----------------------------------------------------------

    def offer(self, req: Request) -> bool:
        """A request arrives. Returns False when rejected (and records
        which bound rejected it)."""
        # the budget bound matters too: a request no in-flight set can ever
        # satisfy would wedge the FCFS head forever
        if self._footprint(req) > min(self.cfg.max_seq_len,
                                      self.cfg.resolved_budget()):
            self.rejected_too_long.append(req)
            return False
        if len(self.queue) >= self.cfg.max_queue:
            self.rejected_full.append(req)
            return False
        self.queue.append(req)
        return True

    def admit(self) -> List[Admission]:
        """Move FCFS queue heads into free slots at a step boundary.

        Stops at the first request that doesn't fit the token budget
        (strict FCFS — no smaller request overtakes, so admission order is
        arrival order and the latency ledger stays honest), at slot
        exhaustion, or at the per-step prefill cap.
        """
        out: List[Admission] = []
        budget = self.cfg.resolved_budget()
        while (self.queue and self.pool.n_free > 0
               and len(out) < self.cfg.max_prefills_per_step):
            req = self.queue[0]
            fp = self._footprint(req)
            if self._budget_used + fp > budget:
                break
            self.queue.pop(0)
            slot = self.pool.alloc()
            self.in_flight[slot] = req
            self._budget_used += fp
            out.append(Admission(request=req, slot=slot))
        return out

    def release(self, slot: int) -> Request:
        """Retire the request occupying ``slot``; frees slot + budget."""
        req = self.in_flight.pop(slot)
        self._budget_used -= self._footprint(req)
        self.pool.free(slot)
        return req

    @property
    def idle(self) -> bool:
        """Nothing queued, nothing in flight."""
        return not self.queue and not self.in_flight
