"""Open-loop arrival processes for the serving driver.

Offered load is *open-loop*: requests arrive on their own schedule whether
or not the server keeps up — the regime where queueing delay explodes past
saturation, which closed-loop (one-in-one-out) load generators can never
show. Two processes:

  * ``poisson`` — exponential inter-arrival times at ``rate_rps``;
  * ``bursty``  — a Markov-modulated Poisson process: the generator
    alternates between a quiet phase at ``rate_rps`` and burst phases at
    ``burst_factor × rate_rps`` (exponentially distributed phase lengths),
    the classic flash-crowd shape.

Prompt and output lengths are sampled per request from bounded geometric
distributions around the configured means. Everything is drawn from one
``numpy.random.RandomState(seed)``, so a ``TrafficConfig`` is a pure
function seed → request list: same seed ⇒ identical arrival times, token
ids, lengths — the property the serve determinism tests pin.

Arrivals meet the engine through the discrete-event machinery the training
runtime already uses: ``offered_load`` schedules one ``"arrival"`` event
per request on a ``runtime.clock.EventQueue`` (modeled seconds, FIFO
tie-breaking), and ``ServeEngine.run`` pops them against its virtual
``Clock``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.runtime.clock import EventQueue


@dataclass(frozen=True)
class Request:
    """One inference request: a prompt, a generation budget, an arrival
    time on the virtual clock.

    ``prompt`` is a concrete int32 token array of shape ``(prompt_len,)``;
    ``n_out`` counts generated tokens *including* the one the prefill's
    last-position logits produce. ``frontend`` optionally carries
    precomputed patch/frame embeddings ``(n_frontend_tokens,
    frontend_dim)`` for frontend archs (threaded through to
    ``TF.prefill``).
    """

    id: int
    arrival_s: float                 # modeled seconds (virtual clock)
    prompt: np.ndarray               # (prompt_len,) int32 token ids
    n_out: int                       # output tokens to generate (>= 1)
    frontend: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Cache footprint of the finished request in token slots
        (prompt + generated; the last generated token is never written
        back, frontend tokens are accounted by the scheduler)."""
        return self.prompt_len + self.n_out


@dataclass(frozen=True)
class TrafficConfig:
    """One open-loop load scenario, fully determined by ``seed``."""

    process: str = "poisson"         # "poisson" | "bursty"
    rate_rps: float = 10.0           # mean arrival rate, requests/s (modeled)
    n_requests: int = 32
    mean_prompt_len: int = 32        # geometric around the mean, >= 1
    max_prompt_len: int = 128
    mean_out_len: int = 16
    max_out_len: int = 64
    # bursty (MMPP) phase structure: bursts run burst_factor × rate_rps,
    # phases last ~mean_phase_s each (exponential)
    burst_factor: float = 8.0
    mean_phase_s: float = 1.0
    seed: int = 0


def _bounded_geometric(rng: np.random.RandomState, mean: int, lo: int,
                       hi: int) -> int:
    """Geometric sample with the given mean, clipped to [lo, hi]."""
    if mean <= lo:
        return lo
    v = rng.geometric(1.0 / float(mean))
    return int(min(max(v, lo), hi))


def generate_requests(tcfg: TrafficConfig, vocab_size: int) -> List[Request]:
    """Materialize the request list for one scenario (sorted by arrival).

    A pure function of (tcfg, vocab_size): one RandomState drives
    inter-arrivals, burst phases, lengths and token ids in a fixed draw
    order, so the trace is reproducible across runs and platforms.
    """
    if tcfg.process not in ("poisson", "bursty"):
        raise ValueError(f"unknown arrival process {tcfg.process!r} "
                         "(expected 'poisson' or 'bursty')")
    rng = np.random.RandomState(tcfg.seed)
    t = 0.0
    # bursty phase state: (burst?, phase end time)
    in_burst, phase_end = False, 0.0
    if tcfg.process == "bursty":
        phase_end = rng.exponential(tcfg.mean_phase_s)
    out: List[Request] = []
    for rid in range(tcfg.n_requests):
        rate = tcfg.rate_rps
        if tcfg.process == "bursty":
            while t >= phase_end:
                in_burst = not in_burst
                phase_end += rng.exponential(tcfg.mean_phase_s)
            if in_burst:
                rate = tcfg.rate_rps * tcfg.burst_factor
        t += rng.exponential(1.0 / rate)
        plen = _bounded_geometric(rng, tcfg.mean_prompt_len, 1,
                                  tcfg.max_prompt_len)
        nout = _bounded_geometric(rng, tcfg.mean_out_len, 1,
                                  tcfg.max_out_len)
        prompt = rng.randint(0, vocab_size, size=(plen,)).astype(np.int32)
        out.append(Request(id=rid, arrival_s=t, prompt=prompt, n_out=nout))
    return out


def offered_load(requests: List[Request]) -> EventQueue:
    """Schedule one ``"arrival"`` event per request on a fresh EventQueue.

    ``event.client`` carries the request id (the queue's fields predate
    serving; the engine resolves ids back to Request objects). Same-time
    arrivals pop in request-id order — the deterministic FIFO tie-break
    the clock guarantees.
    """
    q = EventQueue()
    for r in sorted(requests, key=lambda r: (r.arrival_s, r.id)):
        q.push(r.arrival_s, "arrival", client=r.id)
    return q


def arrival_summary(requests: List[Request]) -> dict:
    """Offered-load stats for reports: achieved rate, token volumes."""
    if not requests:
        return {"n_requests": 0, "rate_rps": 0.0, "prompt_tokens": 0,
                "out_tokens": 0}
    span = max(r.arrival_s for r in requests)
    return {
        "n_requests": len(requests),
        "rate_rps": len(requests) / span if span > 0 else float("inf"),
        "prompt_tokens": int(sum(r.prompt_len for r in requests)),
        "out_tokens": int(sum(r.n_out for r in requests)),
    }
