from repro.sharding.rules import shard, param_specs, DATA_AXIS, MODEL_AXIS, POD_AXIS

__all__ = ["shard", "param_specs", "DATA_AXIS", "MODEL_AXIS", "POD_AXIS"]
