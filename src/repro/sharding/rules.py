"""Sharding rules: mesh axis names + activation constraints + param specs.

The production mesh axes (launch/mesh.py):
  pod   — inter-pod axis (multi-pod only)
  data  — client / batch axis (paper's N clients)
  model — tensor-parallel axis (heads / ffn / experts / vocab)

Model code calls ``shard(x, *spec)`` at layer boundaries; it is a no-op when
no mesh is active (CPU smoke tests) and filters axis names that the active
mesh does not carry, so the same model runs on 1 device, 256 or 512.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def _active_axis_names():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or mesh.empty:
        return None
    return set(mesh.axis_names)


def _filter(entry, names):
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None
    return entry if entry in names else None


def _axis_sizes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or mesh.empty:
        return None
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def shard(x, *spec):
    """with_sharding_constraint that degrades gracefully.

    * no-op off-mesh (CPU smoke tests);
    * filters axis names absent from the active mesh;
    * SKIPS the whole constraint if any requested dim is not divisible by its
      mesh-axis size (e.g. 8 KV heads on a 16-way model axis) — forcing such a
      spec would trigger XLA's "involuntary full rematerialization"; leaving
      it unconstrained lets propagation pick a feasible layout instead.
    """
    sizes = _axis_sizes()
    if not sizes:
        return x
    names = set(sizes)
    fspec = tuple(_filter(e, names) for e in spec)
    for dim, entry in zip(x.shape, fspec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        if dim % total != 0:
            return x
    return jax.lax.with_sharding_constraint(x, P(*fspec))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs.
#
# Leaf-name driven: each rule gives the spec of the *trailing* dims of a leaf
# (the arch dims). Stack dims (layer-scan groups) and the client axis are
# prepended by the caller. ``model``-axis placement follows Megatron layout:
# column-parallel in-proj, row-parallel out-proj, experts sharded on E,
# embeddings on vocab.
# ---------------------------------------------------------------------------

_RULES = {
    # embeddings / head
    "embed": ("model", None),          # (vocab, d)
    "unembed": (None, "model"),        # (d, vocab)
    "proj_frontend": (None, None),     # (frontend_dim, d)
    # attention (gqa)
    "wq": (None, "model"),             # (d, H*hd)
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wo": ("model", None),             # (H*hd, d)
    # attention (mla)
    "w_dq": (None, None),              # (d, q_lora)
    "w_uq": (None, "model"),           # (q_lora, H*(nope+rope))
    "w_dkv": (None, None),             # (d, kv_lora + rope)
    "w_uk": (None, "model"),           # (kv_lora, H*nope)
    "w_uv": (None, "model"),           # (kv_lora, H*v)
    # mlp
    "w_gate": (None, "model"),         # (d, ff)
    "w_up": (None, "model"),
    "w_down": ("model", None),         # (ff, d)
    # moe
    "w_router": (None, None),          # (d, E)
    "we_gate": ("model", None, None),  # (E, d, de)
    "we_up": ("model", None, None),
    "we_down": ("model", None, None),  # (E, de, d)
    # mamba2 / ssd
    "w_in": (None, "model"),           # (d, d_in_proj)
    "w_out_ssm": ("model", None),      # (d_inner, d)
    "conv_w": (None, "model"),         # (d_conv, conv_channels)
    "A_log": ("model",),               # (n_heads,)
    "D": ("model",),
    "dt_bias": ("model",),
    "ssm_norm": ("model",),            # (d_inner,) gated rmsnorm
    # rg-lru
    "w_x": (None, "model"),            # (d, lru)
    "w_gate_lru": (None, "model"),
    "conv_lru": (None, "model"),       # (d_conv, lru)
    "a_param": ("model",),             # (lru,)
    "w_in_gate": ("model", None),      # input-gate proj (lru, lru) row-parallel? keep simple
    "w_out_lru": ("model", None),      # (lru, d)
    "gate_w": ("model", None, None),   # per-channel gate (lru, small)
}

_REPLICATED_SUFFIXES = ("norm", "scale", "bias", "q_norm", "k_norm", "kv_norm")


def spec_for_leaf(name: str, ndim: int, extra_leading: int = 0):
    """PartitionSpec for a named leaf with `extra_leading` stack/client dims."""
    base: Optional[tuple]
    if name in _RULES:
        base = _RULES[name]
    elif any(name.endswith(s) for s in _REPLICATED_SUFFIXES):
        base = (None,) * (ndim - extra_leading)
    else:
        base = (None,) * (ndim - extra_leading)
    lead = (None,) * extra_leading
    spec = lead + tuple(base)
    assert len(spec) == ndim, f"{name}: spec {spec} vs ndim {ndim}"
    return P(*spec)


def param_specs(params, client_axis: Optional[str] = None,
                fsdp_axis: Optional[str] = None):
    """Pytree of PartitionSpecs matching ``params``.

    ``params`` leaves are named by their dict key; stacked-layer dims and the
    optional client axis are leading. client_axis ('data' or 'pod') is placed
    on dim 0 when given (training replicas); remaining leading dims (layer
    stacks) are unsharded. ``fsdp_axis`` (hierarchical mode: 'data') is added
    to the first unsharded weight dim — ZeRO-3-style intra-pod param sharding
    so pod-client replicas of 100B+ models fit HBM.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        name = name or "unnamed"
        base_ndim = _base_ndim(name, leaf.ndim, client_axis)
        extra = leaf.ndim - base_ndim
        spec = spec_for_leaf(name, leaf.ndim, extra_leading=extra)
        entries = list(tuple(spec))
        # Exclusions (§Perf A2/A2'): embed/unembed — FSDP on the table's
        # d_model dim turns every token lookup into a full re-gather; expert
        # weights — grouped dispatch re-gathers FSDP'd experts per group
        # (measured 6.8× collective regression), and they are already E-sharded
        # on `model`.
        if (fsdp_axis is not None and name in _RULES and base_ndim >= 2
                and name not in ("embed", "unembed",
                                 "we_gate", "we_up", "we_down")):
            for i in range(leaf.ndim - base_ndim, leaf.ndim):
                if entries[i] is None:
                    entries[i] = fsdp_axis
                    break
        if client_axis is not None:
            entries[0] = client_axis
        specs.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _base_ndim(name: str, ndim: int, client_axis) -> int:
    if name in _RULES:
        return len(_RULES[name])
    # replicated leaves: assume all leading dims are stack/client dims except
    # the last (the feature dim); scalars pass through.
    return min(ndim, 1)


def feasible_specs(specs, shapes, mesh):
    """Drop spec entries whose dim is not divisible by the mesh-axis product.

    pjit in_shardings (unlike constraints) hard-fail on non-divisible dims
    (e.g. vocab 92553 on a 16-way model axis) — those leaves degrade to
    replicated on that dim. Real deployments pad such dims; we keep the
    assigned configs exact and record the replication in DESIGN.md.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, leaf):
        shape = leaf.shape
        entries = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
        out = []
        for dim, e in zip(shape, entries):
            if e is None:
                out.append(None)
                continue
            axes = e if isinstance(e, (tuple, list)) else (e,)
            tot = 1
            for a in axes:
                tot *= sizes.get(a, 1)
            out.append(e if dim % tot == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# KV / recurrent cache specs (serving)
# ---------------------------------------------------------------------------

_CACHE_RULES = {
    # name -> (base_ndim, spec). Batch dim on data(+pod); heads on model.
    "k": (4, (("data",), None, "model", None)),        # (B, C, KV, hd)
    "v": (4, (("data",), None, "model", None)),
    "ckv": (3, (("data",), None, None)),               # MLA latent (B, C, r)
    "k_rope": (3, (("data",), None, None)),
    "conv": (3, (("data",), None, "model")),           # (B, K-1, ch)
}


def cache_specs(cache, data_axes=("data",), seq_axes=()):
    """PartitionSpec tree for a decode cache pytree (leading stack dims ok).

    ``data_axes`` shard the batch dim; ``seq_axes`` (mutually exclusive in
    practice — used when batch is too small, e.g. long_500k b=1) shard the
    cache sequence dim of k/v/ckv/k_rope buffers.
    """
    data_axes = tuple(data_axes)
    seq_axes = tuple(seq_axes)
    bspec = data_axes if data_axes else None
    sspec = seq_axes if seq_axes else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        if name == "pos":
            specs.append(P())
            continue
        if name == "state":
            # mamba2 (B,H,P,N) vs rglru (B,lru): dispatch on trailing ndim
            base = (bspec, "model", None, None) if leaf.ndim >= 4 \
                else (bspec, "model")
            base_nd = len(base)
        elif name in ("k", "v"):
            base_nd, base = 4, (bspec, sspec, "model", None)
        elif name in ("k_scale", "v_scale"):
            base_nd, base = 3, (bspec, sspec, "model")
        elif name in ("ckv", "k_rope"):
            base_nd, base = 3, (bspec, sspec, None)
        elif name == "conv":
            base_nd, base = 3, (bspec, None, "model")
        else:
            base_nd, base = leaf.ndim, (None,) * leaf.ndim
        extra = leaf.ndim - base_nd
        specs.append(P(*(((None,) * extra) + tuple(base))))
    return jax.tree_util.tree_unflatten(treedef, specs)
