from repro.utils.tree import (
    tree_add,
    tree_scale,
    tree_mean_leading,
    tree_zeros_like,
    tree_stack_leading,
    tree_take,
    tree_l2_norm,
    tree_size,
    tree_bytes,
)
from repro.utils.logging import get_logger

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_mean_leading",
    "tree_zeros_like",
    "tree_stack_leading",
    "tree_take",
    "tree_l2_norm",
    "tree_size",
    "tree_bytes",
    "get_logger",
]
