"""Structured JSONL logging (stdout, no deps).

Every log record is one JSON line:

    {"ts": <unix seconds>, "mono_s": <time.monotonic()>, "level": "info",
     "logger": "stl_sgd", "run_id": "a1b2c3d4", "event": "stage_done",
     ...fields}

plus ``"virtual_time_s"`` when the logger is bound to a virtual clock
(``bind_clock`` — the event runtime's ``runtime.clock.Clock``), so
progress lines from a discrete-event run carry both the host's monotonic
timestamp and the run's modeled time.

``repro.obs`` and the engine stack report progress through this logger
(``Engine.run`` / ``StagewiseDriver`` stage events); the legacy printf
style (``log.info("stage %d", s)``) still works — the formatted text
lands in the ``msg`` field — so call sites migrate incrementally.

Level filtering: ``REPRO_LOG_LEVEL`` env var (debug|info|warning|error,
default info). ``quiet()`` silences a logger for tests.

Sampling / rate limiting: ``log.limit(every_n=..., max_per_s=...)`` keeps
event-runtime logs O(windows) instead of O(events) at cohort scale —
``every_n`` emits one record in n per (level, event) key; ``max_per_s``
caps records per second of the bound virtual clock (host monotonic time
when no clock is bound). Suppression is never silent: dropped records
are counted into the ``log.dropped_lines`` obs counter (labelled by
logger) and the next emitted record carries the cumulative ``dropped``
count since the last one that made it out. Warnings and errors always
bypass the limiter.
"""
from __future__ import annotations

import json
import os
import sys
import time
import uuid
from typing import Any, Dict, Optional

_LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

# one run id per process: every record of one invocation correlates
RUN_ID = uuid.uuid4().hex[:8]


class StructuredLogger:
    """One named JSONL event stream.

    ``event(level, event, **fields)`` is the primitive; ``debug`` /
    ``info`` / ``warning`` / ``error`` are sugar. Fields must be
    JSON-serializable (everything else is stringified).
    """

    def __init__(self, name: str, stream=None, level: Optional[str] = None,
                 run_id: Optional[str] = None):
        self.name = name
        self.stream = stream if stream is not None else sys.stdout
        lvl = level or os.environ.get("REPRO_LOG_LEVEL", "info")
        self.level = _LEVELS.get(lvl.lower(), 20)
        self.run_id = run_id or RUN_ID
        self._clock = None
        # sampling / rate limiting (see limit())
        self._every_n: Optional[int] = None
        self._max_per_s: Optional[float] = None
        self._seen: Dict[tuple, int] = {}
        self._bucket: Optional[int] = None
        self._bucket_n = 0
        self._dropped_pending = 0
        self.dropped_total = 0

    def bind_clock(self, clock) -> "StructuredLogger":
        """Attach a virtual-time source: anything with a ``.now`` seconds
        attribute (``runtime.clock.Clock``) or a 0-arg callable. Records
        then carry ``virtual_time_s``."""
        self._clock = clock
        return self

    def quiet(self) -> "StructuredLogger":
        """Disable output (tests, library consumers)."""
        self.level = 10 ** 9
        return self

    def limit(self, every_n: Optional[int] = None,
              max_per_s: Optional[float] = None) -> "StructuredLogger":
        """Sample / rate-limit records below warning level.

        ``every_n``: emit the 1st of every n records per (level, event)
        key. ``max_per_s``: at most that many records per second of the
        bound virtual clock (host monotonic without one). Drops are
        counted (``log.dropped_lines`` obs counter + a ``dropped`` field
        on the next emitted record). ``limit()`` clears both.
        """
        self._every_n = every_n if every_n and every_n > 1 else None
        self._max_per_s = max_per_s if max_per_s and max_per_s > 0 else None
        self._seen.clear()
        self._bucket, self._bucket_n = None, 0
        return self

    def _now_s(self) -> float:
        vt = self._virtual_now()
        return vt if vt is not None else time.monotonic()

    def _drop(self):
        self._dropped_pending += 1
        self.dropped_total += 1
        try:
            from repro.obs import metrics as obs_metrics

            obs_metrics.registry().counter(
                "log.dropped_lines", unit="records",
                help="log records suppressed by limit()").inc(
                    logger=self.name)
        except Exception:
            pass  # never let accounting break logging

    def _limited(self, level: str, event: str) -> bool:
        """True when this record is suppressed by the limiter."""
        if self._every_n is None and self._max_per_s is None:
            return False
        if _LEVELS.get(level, 20) >= _LEVELS["warning"]:
            return False
        if self._every_n is not None:
            k = (level, event)
            n = self._seen.get(k, 0)
            self._seen[k] = n + 1
            if n % self._every_n != 0:
                self._drop()
                return True
        if self._max_per_s is not None:
            bucket = int(self._now_s() * self._max_per_s)
            if bucket != self._bucket:
                self._bucket, self._bucket_n = bucket, 0
            if self._bucket_n >= 1:
                self._drop()
                return True
            self._bucket_n += 1
        return False

    def _virtual_now(self) -> Optional[float]:
        c = self._clock
        if c is None:
            return None
        now = getattr(c, "now", None)
        if now is None and callable(c):
            now = c()
        return float(now) if now is not None else None

    def event(self, level: str, event: str, *args,
              **fields: Any) -> Optional[Dict[str, Any]]:
        """Emit one record. Legacy printf compat: when ``args`` is
        non-empty, ``event`` is treated as a %-format string and the
        rendered text becomes the ``msg`` field of a generic ``"log"``
        event."""
        if _LEVELS.get(level, 20) < self.level:
            return None
        if args:
            fields = dict(fields, msg=event % args)
            event = "log"
        if self._limited(level, event):
            return None
        if self._dropped_pending:
            fields = dict(fields, dropped=self._dropped_pending)
            self._dropped_pending = 0
        rec: Dict[str, Any] = {"ts": round(time.time(), 6),
                               "mono_s": round(time.monotonic(), 6),
                               "level": level, "logger": self.name,
                               "run_id": self.run_id, "event": event}
        vt = self._virtual_now()
        if vt is not None:
            rec["virtual_time_s"] = vt
        rec.update(fields)
        self.stream.write(json.dumps(rec, default=str) + "\n")
        flush = getattr(self.stream, "flush", None)
        if flush:
            flush()
        return rec

    def debug(self, event: str, *args, **fields):
        return self.event("debug", event, *args, **fields)

    def info(self, event: str, *args, **fields):
        return self.event("info", event, *args, **fields)

    def warning(self, event: str, *args, **fields):
        return self.event("warning", event, *args, **fields)

    def error(self, event: str, *args, **fields):
        return self.event("error", event, *args, **fields)


_loggers: Dict[str, StructuredLogger] = {}


def get_logger(name: str) -> StructuredLogger:
    """Process-cached structured logger (one per name)."""
    if name not in _loggers:
        _loggers[name] = StructuredLogger(name)
    return _loggers[name]
