"""Minimal structured logger (stdout, no deps)."""
from __future__ import annotations

import logging
import sys

_FMT = "%(asctime)s %(levelname).1s %(name)s] %(message)s"


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        h = logging.StreamHandler(sys.stdout)
        h.setFormatter(logging.Formatter(_FMT, datefmt="%H:%M:%S"))
        logger.addHandler(h)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    return logger
