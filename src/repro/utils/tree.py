"""Pytree helpers used across the framework.

These are deliberately tiny wrappers over ``jax.tree_util`` — kept in one
place so algorithm code (core/) reads like the paper's pseudocode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Leafwise a + b."""
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    """Leafwise a - b."""
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    """Leafwise s * a for scalar s."""
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_mean_leading(a):
    """Mean over the leading (client) axis of every leaf.

    This is the parameter-averaging round of Local SGD (Alg. 1 line 5):
    given per-client replicas stacked on axis 0, return the consensus model.
    """
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_broadcast_leading(a, n: int):
    """Replicate a pytree along a new leading axis of size n (client replicas)."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), a)


def tree_stack_leading(trees):
    """Stack a list of pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_take(a, i):
    """Index the leading axis of every leaf (extract client i's replica)."""
    return jax.tree.map(lambda x: x[i], a)


def tree_l2_norm(a):
    """Global l2 norm across all leaves."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(a))
    return jnp.sqrt(sq)


def tree_l2_dist(a, b):
    """||a - b|| across all leaves (used for the prox term in Alg. 3)."""
    return tree_l2_norm(tree_sub(a, b))


def tree_size(a) -> int:
    """Total number of scalar parameters."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(leaves_a) != len(leaves_b):
        return False
    import numpy as np

    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )
