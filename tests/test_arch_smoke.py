"""Per-architecture smoke tests: reduced config, one forward + one train step
+ one decode step on CPU; output shapes and finite values asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.core import local_sgd as LS
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.transformer import padded_vocab


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch, smoke=True)
    params = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = None
    extra = 0
    if cfg.frontend:
        fe = jnp.zeros((B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
        extra = cfg.n_frontend_tokens
    logits, aux = T.forward(params, cfg, toks, fe)
    assert logits.shape == (B, S + extra, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_no_nans(arch, mesh):
    cfg = get_arch(arch, smoke=True)
    C = 2
    state = LS.init_state(jax.random.key(0), cfg, C)
    local_step, sync_step, _ = LS.build_train_steps(cfg, mesh, client_axis="data")
    B, S = 2, 32
    S_text = S - (cfg.n_frontend_tokens if cfg.frontend else 0)
    if S_text <= 0:
        S_text, S = 16, 16 + cfg.n_frontend_tokens
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (C, B, S_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(2), (C, B, S_text), 0, cfg.vocab_size),
    }
    if cfg.frontend:
        batch["frontend"] = jnp.zeros(
            (C, B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    state2, metrics = jax.jit(local_step)(state, batch, 0.01)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(state2["params"])[0]
    assert not jnp.allclose(p0.astype(jnp.float32), p1.astype(jnp.float32))
    # sync: replicas equal afterwards
    state3 = jax.jit(sync_step)(state2)
    for leaf in jax.tree.leaves(state3["params"]):
        a = leaf[0].astype(jnp.float32)
        for i in range(1, C):
            assert jnp.allclose(a, leaf[i].astype(jnp.float32), atol=1e-6)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_shapes(arch):
    cfg = get_arch(arch, smoke=True)
    params = T.init_params(jax.random.key(0), cfg)
    B = 2
    cache = T.init_cache(cfg, B, 64)
    toks = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab_size)
    logits, cache2 = T.decode_step(params, cfg, toks, cache)
    assert logits.shape == (B, 1, padded_vocab(cfg))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == 1
