"""repro.comm: reducer semantics, kernel parity, cost model, EF property.

The decisive invariants:
  * DenseMean is bit-exact with tree_mean_leading, and the reducer-threaded
    round function is bit-exact with the pre-comm-subsystem dense round
    (inline Algorithm 1 reference);
  * the Pallas quantize kernels (interpret mode) match the jnp oracles —
    int8 codes exactly, the fused dequant-mean to f32 tolerance;
  * error feedback rescues a biased compressor: naive top-k sparsification
    stalls on the synthetic logreg problem, the residual-corrected reducer
    converges to the dense objective;
  * the α–β cost model prices compressed rounds ≥ 3× below dense.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    DenseMean,
    NetworkModel,
    QuantizedMean,
    TopKMean,
    comm_summary,
    get_reducer,
    round_bytes,
    round_time,
)
from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core import simulate
from repro.data import make_binary_classification, partition_iid
from repro.kernels.quantize import (
    check_tile_alignment,
    compute_scale,
    dequant_mean,
    quantize,
)
from repro.models import logreg
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading


# ---------------------------------------------------------------------------
# Reducer semantics
# ---------------------------------------------------------------------------

def _stacked(seed=0, n=4):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (n, 33, 7)),
            "b": jax.random.normal(k2, (n, 5))}


def test_dense_mean_bit_exact():
    stacked = _stacked()
    red = DenseMean()
    mean, state = red.reduce(stacked, red.init_state(stacked),
                             jax.random.key(1))
    ref = tree_mean_leading(stacked)
    for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("red", [QuantizedMean(bits=8), TopKMean(frac=0.25)])
def test_compressed_reduce_flushes_to_mean(red):
    """Protocol-faithful fixed point: clients diverge once (one round of
    local progress), then idle at the broadcast consensus. Error feedback
    must flush the dropped mass so the consensus converges to the exact
    dense mean of the diverged replicas."""
    base = {"w": jax.random.normal(jax.random.key(0), (33, 7)),
            "b": jax.random.normal(jax.random.key(1), (5,))}
    offsets = _stacked(seed=2)
    stacked0 = tree_broadcast_leading(base, 4)
    state = red.init_state(stacked0)
    diverged = jax.tree.map(lambda b, o: b + 0.1 * o, stacked0, offsets)
    target = tree_mean_leading(diverged)
    mean, state = red.reduce(diverged, state, jax.random.key(3))
    for i in range(12):  # clients idle at consensus; residuals drain
        mean, state = red.reduce(tree_broadcast_leading(mean, 4), state,
                                 jax.random.key(4 + i))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(target)))
    assert err < 1e-3, err


def test_reduce_is_scan_safe():
    stacked = _stacked()
    red = QuantizedMean(bits=4)

    def body(carry, rng):
        mean, carry = red.reduce(stacked, carry, rng)
        return carry, mean["b"].sum()

    _, out = jax.jit(lambda s: jax.lax.scan(
        body, s, jax.random.split(jax.random.key(0), 3)))(
            red.init_state(stacked))
    assert out.shape == (3,) and bool(jnp.all(jnp.isfinite(out)))


def test_get_reducer_specs():
    assert isinstance(get_reducer(None), DenseMean)
    assert isinstance(get_reducer("dense"), DenseMean)
    assert get_reducer("int4").bits == 4
    assert get_reducer("quant", quant_bits=2).bits == 2
    assert get_reducer("topk", topk_frac=0.25).frac == 0.25
    r = QuantizedMean(bits=8)
    assert get_reducer(r) is r
    with pytest.raises(ValueError):
        get_reducer("bogus")


# ---------------------------------------------------------------------------
# Round-function regression: reducer-threaded round == pre-PR dense round
# ---------------------------------------------------------------------------

def test_round_fn_dense_bit_exact_with_alg1_reference():
    """make_round_fn(reducer=DenseMean) must reproduce the original dense
    Algorithm 1 round (k vmapped SGD steps + mean over replicas) bit-for-bit,
    including the rng stream."""
    d, N, k, batch, eta = 8, 4, 3, 8, 0.2
    key = jax.random.key(0)
    data = {"x": jax.random.normal(key, (N, 64, d)),
            "y": (jax.random.normal(jax.random.fold_in(key, 1), (N, 64))
                  > 0).astype(jnp.float32)}
    params = tree_broadcast_leading({"w": jnp.zeros((d,)),
                                     "b": jnp.zeros(())}, N)
    mom = jax.tree.map(jnp.zeros_like, params)

    def wloss(p, b, center, weights):
        logit = b["x"] @ p["w"] + p["b"]
        return jnp.mean(jnp.square(logit - b["y"]))

    round_fn = simulate.make_round_fn(
        wloss, k=k, batch=batch, momentum=0.0, lr_alpha=0.0, grow=1.0,
        b0=batch, max_batch=batch)
    rng_r = jax.random.key(7)
    got_p, got_m, got_t, _ = round_fn(
        (params, mom, jnp.asarray(0.0, jnp.float32), None),
        rng_r, data, None, eta)

    # inline pre-PR reference (seed-commit make_round_fn body, dense mean)
    def local_step(c, rng_t):
        p, m, t = c

        def client(pp, mm, dd, rng):
            b = simulate._sample_batch(dd, rng, batch)
            g = jax.grad(lambda q: wloss(q, b, None, None))(pp)
            m2 = jax.tree.map(lambda a, gg: 0.0 * a + gg, mm, g)
            p2 = jax.tree.map(lambda a, mm2: a - eta * mm2, pp, m2)
            return p2, m2

        rngs = jax.random.split(rng_t, N)
        p, m = jax.vmap(client)(p, m, data, rngs)
        return (p, m, t + 1.0), None

    (ref_p, ref_m, ref_t), _ = jax.lax.scan(
        local_step, (params, mom, 0.0), jax.random.split(rng_r, k))
    ref_p = tree_broadcast_leading(tree_mean_leading(ref_p), N)
    ref_m = tree_broadcast_leading(tree_mean_leading(ref_m), N)
    for a, b in zip(jax.tree.leaves(got_p), jax.tree.leaves(ref_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(got_m), jax.tree.leaves(ref_m)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(got_t) == float(ref_t)


# ---------------------------------------------------------------------------
# Pallas kernel parity (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("size", [1000, 70001])
def test_quantize_kernel_matches_ref(bits, size):
    x = jax.random.normal(jax.random.key(0), (size,), jnp.float32)
    rbits = jax.random.bits(jax.random.key(1), (size,), jnp.uint32)
    s = compute_scale(x)
    q_ref = quantize(x, rbits, s, bits=bits, impl="xla")
    q_ker = quantize(x, rbits, s, bits=bits, impl="interpret")
    assert q_ref.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_ker))
    qmax = 2 ** (bits - 1) - 1
    assert int(jnp.max(jnp.abs(q_ref.astype(jnp.int32)))) <= qmax


@pytest.mark.parametrize("bits", [8, 4])
def test_dequant_mean_kernel_matches_ref(bits):
    N, M = 5, 3000
    x = jax.random.normal(jax.random.key(0), (N, M), jnp.float32)
    rbits = jax.random.bits(jax.random.key(1), (N, M), jnp.uint32)
    scales = jnp.max(jnp.abs(x), axis=1)
    q = jnp.stack([quantize(x[i], rbits[i], scales[i], bits=bits)
                   for i in range(N)])
    m_ref = dequant_mean(q, scales, bits=bits, impl="xla")
    m_ker = dequant_mean(q, scales, bits=bits, impl="interpret")
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_ker),
                               rtol=1e-6, atol=1e-6)
    # fused dequant-mean approximates the true mean at int8
    if bits == 8:
        np.testing.assert_allclose(np.asarray(m_ker), np.asarray(x.mean(0)),
                                   atol=2 * float(scales.max()) / 127)


def test_quantized_mean_interpret_impl_matches_xla():
    stacked = _stacked(n=3)
    rngs = jax.random.key(5)
    out = {}
    for impl in ("xla", "interpret"):
        red = QuantizedMean(bits=8, impl=impl)
        mean, _ = red.reduce(stacked, red.init_state(stacked), rngs)
        out[impl] = mean
    for a, b in zip(jax.tree.leaves(out["xla"]),
                    jax.tree.leaves(out["interpret"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(5,), (33, 7), (257,), (100,)])
def test_quantize_kernel_misaligned_shapes_pad_to_int8_tile(shape):
    """Regression: inputs that don't fill a (32, 128) int8 tile are padded,
    not silently mis-tiled — and remain bit-exact with the oracle. A small
    custom block exercises the padding path rather than hiding behind the
    64K default."""
    x = jax.random.normal(jax.random.key(0), shape, jnp.float32)
    rbits = jax.random.bits(jax.random.key(1), shape, jnp.uint32)
    s = compute_scale(x)
    q_ref = quantize(x, rbits, s, impl="xla")
    q_ker = quantize(x, rbits, s, impl="interpret", block=4096)
    assert q_ker.shape == shape
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_ker))
    N = 3
    xs = jnp.stack([x.reshape(-1)] * N) + jnp.arange(N)[:, None] * 0.1
    rb = jax.random.bits(jax.random.key(2), xs.shape, jnp.uint32)
    scales = jnp.max(jnp.abs(xs), axis=1)
    q = jnp.stack([quantize(xs[i], rb[i], scales[i]) for i in range(N)])
    m_ref = dequant_mean(q, scales, impl="xla")
    m_ker = dequant_mean(q, scales, impl="interpret", block=4096)
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_ker),
                               rtol=1e-6, atol=1e-6)


def test_quantize_kernel_rejects_misaligned_block():
    """Blocks that don't pad to whole (32, 128) int8 tiles must raise in
    every kernel mode instead of relying on interpret-mode leniency."""
    x = jax.random.normal(jax.random.key(0), (100,), jnp.float32)
    rbits = jax.random.bits(jax.random.key(1), (100,), jnp.uint32)
    s = compute_scale(x)
    assert check_tile_alignment(4096) == 4096
    assert check_tile_alignment(65536) == 65536
    for bad in (128, 1000, 4095, 4097, 0, -4096):
        with pytest.raises(ValueError):
            check_tile_alignment(bad)
        with pytest.raises(ValueError):
            quantize(x, rbits, s, impl="interpret", block=bad)
    with pytest.raises(ValueError):
        dequant_mean(jnp.zeros((2, 100), jnp.int8), jnp.ones((2,)),
                     impl="interpret", block=129)


# ---------------------------------------------------------------------------
# Error-feedback property on the synthetic logreg problem
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def logreg_problem():
    x, y = make_binary_classification(n=2048, d=32, seed=0)
    lam = 1e-2
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=0).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    p0 = logreg.init_params(None, 32)
    p = p0
    g = jax.jit(jax.grad(eval_fn))
    for _ in range(2000):
        p = jax.tree.map(lambda a, b: a - 1.0 * b, p, g(p))
    return loss_fn, eval_fn, p0, data, float(eval_fn(p))


def _gap(problem, reducer):
    loss_fn, eval_fn, p0, data, fstar = problem
    cfg = TrainConfig(algo="local", eta1=0.3, T1=512, k1=4.0, n_stages=2,
                      iid=True, batch_per_client=16, seed=0)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=64,
                        reducer=reducer)
    return hist[-1].value - fstar


def test_error_feedback_rescues_biased_compressor(logreg_problem):
    """Naive (no-residual) top-k sparsification of the round deltas stalls
    an order of magnitude above the optimum; the same compressor with error
    feedback converges to the dense objective."""
    gap_naive = _gap(logreg_problem, TopKMean(frac=0.03,
                                              error_feedback=False))
    gap_ef = _gap(logreg_problem, TopKMean(frac=0.03, error_feedback=True))
    gap_dense = _gap(logreg_problem, None)
    assert gap_ef < 2e-3, gap_ef
    assert gap_naive > 10 * gap_ef, (gap_naive, gap_ef)
    assert abs(gap_ef - gap_dense) < 2e-3


def test_quantized_ef_matches_dense_at_2_bits(logreg_problem):
    """Even 2-bit stochastic delta quantization with EF lands on the dense
    objective (the residual absorbs the coarse lattice)."""
    gap_q2 = _gap(logreg_problem, QuantizedMean(bits=2))
    gap_dense = _gap(logreg_problem, None)
    assert abs(gap_q2 - gap_dense) < 2e-3, (gap_q2, gap_dense)


def test_simulate_dense_reducer_arg_is_default():
    """reducer=DenseMean() and the default path produce identical traces."""
    x, y = make_binary_classification(n=512, d=8, seed=0)
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 2, seed=0).items()}
    loss_fn = lambda p, b: logreg.loss_fn(p, b, 1e-2)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, 1e-2))
    p0 = logreg.init_params(None, 8)
    cfg = TrainConfig(algo="stl_sc", eta1=0.2, T1=16, k1=2.0, n_stages=3,
                      iid=True, batch_per_client=8, seed=0)
    h1 = simulate.run(loss_fn, p0, data, cfg, eval_fn)
    h2 = simulate.run(loss_fn, p0, data, cfg, eval_fn, reducer=DenseMean())
    assert [(r.round, r.value) for r in h1] == \
        [(r.round, r.value) for r in h2]


# ---------------------------------------------------------------------------
# Distributed sync_step + cost model
# ---------------------------------------------------------------------------

def test_build_sync_step_dense_preserves_contract():
    params = _stacked(n=4)
    state = {"params": params,
             "opt": {"mu": jnp.zeros((4, 33, 7))},
             "step": jnp.zeros((), jnp.int32)}
    out = jax.jit(LS.build_sync_step())(state)
    assert set(out.keys()) == {"params", "opt", "step"}
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"][0]),
        np.asarray(tree_mean_leading(params)["w"]))


def test_build_sync_step_compressed_round():
    params = _stacked(n=4)
    state = {"params": params,
             "opt": {"mu": jnp.zeros((4, 33, 7))},
             "step": jnp.zeros((), jnp.int32)}
    sync = LS.build_sync_step("int8")
    out = jax.jit(sync)(state)
    assert "comm" in out
    # replicas agree post-sync and sit near the dense mean
    np.testing.assert_array_equal(np.asarray(out["params"]["w"][0]),
                                  np.asarray(out["params"]["w"][1]))
    err = float(jnp.max(jnp.abs(out["params"]["w"][0]
                                - tree_mean_leading(params)["w"])))
    assert err < 0.1, err
    jax.jit(sync)(out)  # second round with comm state threaded


def test_train_sync_loop_threads_comm_state():
    """Regression: train_step_local must not drop the "comm" key — otherwise
    a compressed sync re-initializes its error-feedback residuals (and
    reference) from the diverged replicas every round, silently degrading to
    the naive compressor. Drives the real build_train_steps/build_sync_step
    pair for two full train->sync rounds."""
    from repro.configs.base import ArchConfig

    cfg = ArchConfig()  # loss_fn below ignores it
    C, d = 3, 16

    def toy_loss(params, _cfg, batch):
        return jnp.mean(jnp.square(batch["x"] @ params["w"] - batch["y"]))

    train_step, sync_step, _ = LS.build_train_steps(
        cfg, None, loss_fn=toy_loss, reducer="int8")
    assert sync_step.reducer.name == "int8"
    key = jax.random.key(0)
    state = {"params": tree_broadcast_leading(
                 {"w": jax.random.normal(key, (d,))}, C),
             "opt": {"mu": {"w": jnp.zeros((C, d))}},
             "step": jnp.zeros((), jnp.int32)}
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 1), (C, 8, d)),
             "y": jax.random.normal(jax.random.fold_in(key, 2), (C, 8))}
    state, _ = train_step(state, batch, 0.1)
    state = sync_step(state)
    assert "comm" in state
    # EF is live: the quantizer's residual is nonzero after a real round
    assert float(jnp.max(jnp.abs(state["comm"]["res"]["w"]))) > 0.0
    state, _ = train_step(state, batch, 0.1)
    assert "comm" in state, "train_step_local dropped the comm state"
    state = sync_step(state)
    # the reference tracks the broadcast consensus exactly
    np.testing.assert_array_equal(np.asarray(state["comm"]["ref"]["w"]),
                                  np.asarray(state["params"]["w"][0]))
    # and the driver picks the accounting reducer off the tagged sync_step
    from repro.core.stl_sgd import StagewiseDriver

    drv = StagewiseDriver(TrainConfig(algo="local", T1=4, k1=2.0, n_stages=1),
                          train_step, jax.jit(sync_step))
    assert drv.reducer.name == "int8"


def test_cost_model_prices_compression():
    tmpl = {"w": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    net = NetworkModel(latency_s=1e-2, bandwidth_gbps=1.0)
    dense_b = round_bytes(DenseMean(), tmpl, 8, net)
    int8_b = round_bytes(QuantizedMean(bits=8), tmpl, 8, net)
    topk_b = round_bytes(TopKMean(frac=0.1), tmpl, 8, net)
    assert dense_b == 8 * 4000
    assert dense_b / int8_b > 3.0
    assert dense_b / topk_b > 3.0
    assert round_time(net, 0) == pytest.approx(1e-2)
    assert round_time(net, net.bandwidth_Bps) == pytest.approx(1.0 + 1e-2)
    summ = comm_summary(QuantizedMean(bits=8), tmpl, 8, 10, net)
    assert summ["total_bytes"] == summ["bytes_per_round"] * 10
    assert summ["reducer"] == "int8"
