"""Dry-run integration on a small fake mesh (subprocess so XLA's device-count
flag doesn't leak into the main test process)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "@SRC@")
import jax, json, dataclasses
from repro.configs import get_arch, SHAPES
from repro.core import local_sgd as LS
from repro.launch import specs as SP
from repro.launch import hlo_analysis as H
from repro.launch.mesh import _make_mesh, mesh_context

mesh = _make_mesh((2, 4), ("data", "model"))
cfg = get_arch("@ARCH@", smoke=True)
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=8)
state, batch, st_sh, b_sh, ca = SP.train_specs(cfg, shape, mesh)
with mesh_context(mesh):
    local_step, sync_step, _ = LS.build_train_steps(cfg, mesh, client_axis=ca,
                                                    microbatch=2)
    cl = jax.jit(local_step, in_shardings=(st_sh, b_sh, None),
                 out_shardings=(st_sh, None)).lower(state, batch, 0.1).compile()
    cs = jax.jit(sync_step, in_shardings=(st_sh,),
                 out_shardings=st_sh).lower(state).compile()
shape_d = dict(zip(mesh.axis_names, mesh.devices.shape))
loc = H.collective_summary(H.parse_collectives_nested(cl.as_text(), shape_d))
syn = H.collective_summary(H.parse_collectives_nested(cs.as_text(), shape_d))
print(json.dumps({"local": loc, "sync": syn}))
"""


@pytest.mark.parametrize("arch", ["qwen3-14b", "phi3.5-moe-42b-a6.6b",
                                  "mamba2-2.7b", "recurrentgemma-2b"])
def test_local_step_has_no_client_axis_traffic(arch):
    script = SCRIPT.replace("@SRC@", os.path.abspath(SRC)).replace("@ARCH@", arch)
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # local step: data-axis traffic must be negligible — O(KB) control plane
    # (loss metrics; on MoE archs GSPMD also reshards the aux-loss scalars,
    # ~32KB) vs O(100MB+) parameter state moved by the sync round below.
    data_bytes = sum(v for k, v in res["local"]["by_axes"].items()
                     if "data" in k)
    assert data_bytes < 1e5, res["local"]
    # the averaging round must move real data over the client axis
    sync_data = sum(v for k, v in res["sync"]["by_axes"].items()
                    if "data" in k)
    assert sync_data > 1e5, res["sync"]
