"""repro.engine: registry round-trips, bit-exact regression, topologies.

The decisive invariants:
  * every historical algo name resolves through the registry to an
    Algorithm whose SyncPolicy reproduces the old make_stages schedule;
  * stl_sc + DenseMean under the new Engine reproduces the pre-refactor
    ``simulate.run`` objective trace bit-exactly (golden values captured
    from the pre-engine revision of core/simulate.py);
  * the previously untested algorithms (stl_nc2, crpsgd) run end-to-end
    through both backends (vmapped simulator and StagewiseDriver);
  * the Hierarchical topology composes a dense intra-pod reduce with a
    compressed inter-pod reduce, reports per-hop α–β costs, and its
    error feedback converges to the dense consensus.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import DenseMean, NetworkModel, QuantizedMean, comm_summary_for
from repro.configs.base import TrainConfig
from repro.core import schedules as S
from repro.core import simulate
from repro.core.stl_sgd import StagewiseDriver
from repro.data import make_binary_classification, partition_iid
from repro.engine import (
    Engine,
    EveryStep,
    FixedPeriod,
    GrowingBatchUpdate,
    Hierarchical,
    LargeBatchUpdate,
    SgdUpdate,
    StagewiseGeometric,
    StagewiseLinear,
    Star,
    algorithm_names,
    get_algorithm,
    get_topology,
    topology_for,
)
from repro.models import logreg
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading

ALL_ALGOS = ("sync", "lb", "crpsgd", "local", "stl_sc", "stl_nc1", "stl_nc2")


# ---------------------------------------------------------------------------
# Registry round-trips
# ---------------------------------------------------------------------------

def test_registry_knows_all_seven_names():
    assert set(ALL_ALGOS) <= set(algorithm_names())
    for name in ALL_ALGOS:
        algo = get_algorithm(name)
        assert algo.name == name
        assert get_algorithm(algo) is algo  # Algorithm passes through


def test_registry_rejects_unknown():
    with pytest.raises(ValueError):
        get_algorithm("bogus")


def test_registry_policy_and_update_composition():
    assert isinstance(get_algorithm("sync").sync_policy, EveryStep)
    assert isinstance(get_algorithm("lb").local_update, LargeBatchUpdate)
    assert isinstance(get_algorithm("crpsgd").local_update,
                      GrowingBatchUpdate)
    assert isinstance(get_algorithm("local").sync_policy, FixedPeriod)
    assert isinstance(get_algorithm("stl_sc").sync_policy,
                      StagewiseGeometric)
    assert isinstance(get_algorithm("stl_nc1").sync_policy,
                      StagewiseGeometric)
    assert isinstance(get_algorithm("stl_nc2").sync_policy, StagewiseLinear)
    # prox-center policy: only ^nc re-centers, and only with gamma_inv > 0
    assert get_algorithm("stl_nc1").sync_policy.recenter
    assert not get_algorithm("stl_sc").sync_policy.recenter
    cfg = TrainConfig(algo="stl_nc1", gamma_inv=0.1)
    assert get_algorithm("stl_nc1").uses_center(cfg)
    assert not get_algorithm("stl_nc1").uses_center(
        TrainConfig(algo="stl_nc1", gamma_inv=0.0))
    assert not get_algorithm("stl_sc").uses_center(cfg)


@pytest.mark.parametrize("algo", ALL_ALGOS)
@pytest.mark.parametrize("iid", [True, False])
def test_policy_stages_match_make_stages(algo, iid):
    """make_stages (the historical entry point) and the SyncPolicy agree."""
    via_name = S.make_stages(algo, 0.4, 100, 4.0, 5, iid)
    via_policy = get_algorithm(algo).sync_policy.stages(0.4, 100, 4.0, 5, iid)
    assert via_name == via_policy
    assert len(via_name) == 5
    assert all(st.k >= 1 for st in via_name)


def test_local_update_batch_rules():
    cfg = TrainConfig(batch_per_client=32, max_batch=512, batch_growth=1.1)
    assert SgdUpdate().round_batch(cfg) == 32
    assert LargeBatchUpdate().round_batch(cfg) == 128   # ×4, the lb rule
    assert GrowingBatchUpdate().round_batch(cfg) == 512  # masked max buffer
    assert SgdUpdate().growth(cfg) == 1.0
    assert GrowingBatchUpdate().growth(cfg) == pytest.approx(1.1)


# ---------------------------------------------------------------------------
# Bit-exact regression: Engine vs the pre-refactor simulate.run trace
# ---------------------------------------------------------------------------

# (round, iteration, objective) trace of the pre-engine core/simulate.py
# (commit f5d4d18) on the problem below — stl_sc + DenseMean, seed 0.
_GOLDEN_STL_SC = [
    (0, 0, 0.6931471824645996), (1, 2, 0.6789301633834839),
    (2, 4, 0.6675747632980347), (3, 6, 0.6584702134132385),
    (4, 8, 0.6506574749946594), (5, 10, 0.6422803997993469),
    (6, 12, 0.6323944926261902), (7, 14, 0.6238881945610046),
    (8, 16, 0.6179242134094238), (9, 20, 0.6117205619812012),
    (10, 24, 0.6056254506111145), (11, 28, 0.5996546149253845),
    (12, 32, 0.595111608505249), (13, 36, 0.5898059010505676),
    (14, 40, 0.5841207504272461), (15, 44, 0.5793169140815735),
    (16, 48, 0.5756109356880188), (17, 56, 0.5715053081512451),
    (18, 64, 0.5678795576095581), (19, 72, 0.564716100692749),
    (20, 80, 0.5618601441383362), (21, 88, 0.558756411075592),
    (22, 96, 0.5559707283973694), (23, 104, 0.5533583164215088),
    (24, 112, 0.5510061979293823), (25, 128, 0.5486454963684082),
    (26, 144, 0.5460535883903503), (27, 160, 0.5438601970672607),
    (28, 176, 0.541716456413269), (29, 192, 0.5395599603652954),
    (30, 208, 0.5375436544418335), (31, 224, 0.5357033014297485),
    (32, 240, 0.53408282995224),
]

# same revision: stl_sc Non-IID, momentum=0.9, lr_alpha=1e-3, chunk_rounds=4
# (exercises chunk boundaries, eval_every>1 and the k=√2 growth floor)
_GOLDEN_STL_SC_MOM = [
    (0, 0, 0.6931471824645996), (2, 6, 0.6386178731918335),
    (4, 12, 0.5672575235366821), (6, 20, 0.538230836391449),
    (8, 28, 0.5201643109321594), (10, 36, 0.509807288646698),
    (12, 48, 0.5066706538200378), (14, 60, 0.5050743818283081),
    (16, 72, 0.5042514204978943), (18, 84, 0.5039029717445374),
]


@pytest.fixture(scope="module")
def golden_problem():
    x, y = make_binary_classification(n=512, d=16, seed=3)
    lam = 1e-2
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=0).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = lambda p: logreg.full_objective(p, xj, yj, lam)
    return loss_fn, eval_fn, logreg.init_params(None, 16), data


def test_engine_stl_sc_dense_bit_exact_with_pre_refactor_trace(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = TrainConfig(algo="stl_sc", eta1=0.5, T1=16, k1=2.0, n_stages=4,
                      iid=True, batch_per_client=8, seed=0)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=1)
    got = [(h.round, h.iteration, float(h.value)) for h in hist]
    assert got == [(r, i, v) for r, i, v in _GOLDEN_STL_SC]


def test_engine_stl_sc_momentum_chunked_bit_exact(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = TrainConfig(algo="stl_sc", eta1=0.3, T1=12, k1=3.0, n_stages=3,
                      iid=False, batch_per_client=8, momentum=0.9, seed=7)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=2,
                        lr_alpha=1e-3, chunk_rounds=4)
    got = [(h.round, h.iteration, float(h.value)) for h in hist]
    assert got == [(r, i, v) for r, i, v in _GOLDEN_STL_SC_MOM]


# ---------------------------------------------------------------------------
# Previously-untested algorithms end-to-end through the engine
# ---------------------------------------------------------------------------

def test_crpsgd_simulator_end_to_end(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = TrainConfig(algo="crpsgd", eta1=0.5, T1=64, k1=1.0, n_stages=3,
                      iid=True, batch_per_client=8, batch_growth=1.05,
                      max_batch=32, seed=0)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=16)
    assert hist[-1].value < hist[0].value * 0.8
    # EveryStep policy: one round per iteration
    assert hist[-1].round == hist[-1].iteration


def test_stl_nc2_simulator_end_to_end(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = TrainConfig(algo="stl_nc2", eta1=0.4, T1=32, k1=2.0, n_stages=4,
                      iid=True, gamma_inv=0.2, batch_per_client=8, seed=0)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8)
    assert hist[-1].value < hist[0].value * 0.9
    # linear policy: T_s = s·T1 ⇒ total iters = T1·S(S+1)/2
    assert hist[-1].iteration == 32 * (1 + 2 + 3 + 4)


def _toy_driver(algo, uses_center=False, **cfg_kw):
    """Tiny quadratic client model through the real StagewiseDriver."""
    C, d = 4, 8
    key = jax.random.key(0)
    target = jax.random.normal(key, (d,))

    def train_step(state, batch, eta, center=None):
        def per_client(p, b):
            g = p - target + 0.01 * b
            if center is not None:
                g = g + 0.2 * (p - center)
            return p - eta * g
        params = jax.vmap(per_client)(state["params"], batch)
        loss = float(jnp.mean(jnp.square(params - target)))
        return dict(state, params=params, step=state["step"] + 1), {
            "loss": jnp.asarray(loss)}

    def sync_step(state):
        mean = tree_mean_leading(state["params"])
        return dict(state, params=tree_broadcast_leading(mean, C))

    def batches():
        rng = np.random.RandomState(0)
        while True:
            yield jnp.asarray(rng.randn(C, d).astype(np.float32))

    tcfg = TrainConfig(algo=algo, **cfg_kw)
    state = {"params": jnp.zeros((C, d)), "step": jnp.zeros((), jnp.int32)}
    drv = StagewiseDriver(tcfg, train_step, sync_step,
                          uses_center=uses_center)
    return drv.run(state, batches()), target


def test_crpsgd_driver_end_to_end():
    ds, target = _toy_driver("crpsgd", eta1=0.1, T1=32, k1=1.0, n_stages=2)
    assert ds.iters_total == 64
    assert ds.rounds_total == 64  # k=1: every step syncs
    err = float(jnp.max(jnp.abs(ds.state["params"][0] - target)))
    assert err < 0.2, err
    assert ds.comm_bytes_total > 0 and ds.comm_time_s > 0


def test_stl_nc2_driver_end_to_end():
    ds, target = _toy_driver("stl_nc2", uses_center=True, eta1=0.2, T1=16,
                             k1=2.0, n_stages=3, gamma_inv=0.1)
    # linear schedule: iters = 16·(1+2+3), rounds = Σ ceil(T_s/k_s)
    assert ds.iters_total == 16 * 6
    stages = S.make_stages("stl_nc2", 0.2, 16, 2.0, 3, True)
    assert ds.rounds_total == sum(-(-st.T // st.k) for st in stages)
    assert ds.center is not None  # prox center was re-set per stage
    err = float(jnp.max(jnp.abs(ds.state["params"][0] - target)))
    assert err < 0.2, err


def test_driver_accounting_matches_comm_summary():
    """The engine ledger and the post-hoc comm_summary_for agree."""
    ds, _ = _toy_driver("local", eta1=0.1, T1=8, k1=2.0, n_stages=2)
    cfg = TrainConfig(algo="local", T1=8, k1=2.0, n_stages=2)
    tmpl = {"params": jax.ShapeDtypeStruct((8,), jnp.float32)}
    summ = comm_summary_for(cfg, tmpl["params"], 4, ds.rounds_total)
    assert ds.comm_bytes_total == summ["total_bytes"]
    assert ds.comm_time_s == pytest.approx(summ["total_time_s"])


# ---------------------------------------------------------------------------
# Topology: Star bit-compat, Hierarchical composition + per-hop costs
# ---------------------------------------------------------------------------

def _stacked(n=8):
    k1, k2 = jax.random.split(jax.random.key(0))
    return {"w": jax.random.normal(k1, (n, 17, 3)),
            "b": jax.random.normal(k2, (n, 5))}


def test_star_dense_is_plain_mean():
    stacked = _stacked()
    topo = Star(reducer=DenseMean())
    mean, _ = topo.reduce(stacked, topo.init_state(stacked),
                          jax.random.key(1))
    ref = tree_mean_leading(stacked)
    for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hierarchical_dense_dense_matches_global_mean():
    stacked = _stacked()
    topo = Hierarchical(n_pods=2, intra=DenseMean(), inter=DenseMean())
    mean, _ = topo.reduce(stacked, topo.init_state(stacked),
                          jax.random.key(1))
    ref = tree_mean_leading(stacked)
    for a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_hierarchical_int8_inter_ef_converges_to_dense():
    """Dense intra-pod + int8-EF inter-pod: repeated rounds at a fixed
    divergence drain the residual onto the dense consensus."""
    stacked = _stacked()
    topo = Hierarchical(n_pods=2, intra=DenseMean(),
                        inter=QuantizedMean(bits=8))
    state = topo.init_state(stacked)
    target = tree_mean_leading(stacked)
    mean, state = topo.reduce(stacked, state, jax.random.key(2))
    for i in range(12):
        mean, state = topo.reduce(tree_broadcast_leading(mean, 8), state,
                                  jax.random.key(3 + i))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(mean),
                              jax.tree.leaves(target)))
    assert err < 1e-3, err


def test_hierarchical_reduce_is_jit_and_scan_safe():
    stacked = _stacked()
    topo = Hierarchical(n_pods=2, intra=DenseMean(),
                        inter=QuantizedMean(bits=8))

    def body(carry, rng):
        mean, carry = topo.reduce(stacked, carry, rng)
        return carry, mean["b"].sum()

    _, out = jax.jit(lambda s: jax.lax.scan(
        body, s, jax.random.split(jax.random.key(0), 3)))(
            topo.init_state(stacked))
    assert out.shape == (3,) and bool(jnp.all(jnp.isfinite(out)))


def test_hierarchical_rejects_indivisible_pods():
    stacked = _stacked(n=6)
    with pytest.raises(ValueError):
        Hierarchical(n_pods=4).init_state(stacked)


def test_hop_costs_per_hop_networks():
    tmpl = {"w": jax.ShapeDtypeStruct((1000,), jnp.float32)}
    topo = Hierarchical(
        n_pods=2, intra=DenseMean(), inter=QuantizedMean(bits=8),
        intra_net=NetworkModel(latency_s=1e-6, bandwidth_gbps=400.0),
        inter_net=NetworkModel(latency_s=5e-3, bandwidth_gbps=1.0))
    hops = topo.hop_costs(tmpl, n_clients=8)
    assert [h.hop for h in hops] == ["intra_pod", "inter_pod"]
    intra, inter = hops
    assert intra.bytes == 8 * 4000            # dense f32 uplink × 8 clients
    assert inter.bytes == 2 * (1000 + 4)      # int8 codes + scale, × 2 pods
    # intra pods reduce in parallel: time prices one pod's 4 messages
    assert intra.time_s == pytest.approx(1e-6 + 4 * 4000 / (400e9 / 8))
    assert inter.time_s == pytest.approx(5e-3 + inter.bytes / (1e9 / 8))
    assert topo.round_bytes(tmpl, 8) == intra.bytes + inter.bytes
    assert topo.round_time(tmpl, 8) == pytest.approx(
        intra.time_s + inter.time_s)
    summ = topo.summary(tmpl, 8, 10)
    assert summ["total_bytes"] == 10 * topo.round_bytes(tmpl, 8)
    assert len(summ["hops"]) == 2
    assert summ["hops"][1]["reducer"] == "int8"


def test_get_topology_specs():
    star = get_topology("star", reducer="dense")
    assert isinstance(star, Star) and isinstance(star.reducer, DenseMean)
    hier = get_topology("hier", reducer="dense", n_pods=4,
                        inter_reducer="int4")
    assert isinstance(hier, Hierarchical)
    assert hier.n_pods == 4 and hier.inter.bits == 4
    assert get_topology(star) is star
    with pytest.raises(ValueError):
        get_topology("ring")
    cfg = TrainConfig(topology="hier", n_pods=2, inter_reducer="int8")
    assert isinstance(topology_for(cfg), Hierarchical)
    assert isinstance(topology_for(TrainConfig()), Star)


def test_simulator_hierarchical_topology_end_to_end(golden_problem):
    """stl_sc over 2 pods (dense ICI + int8 WAN) lands on the flat-dense
    objective — the engine acceptance demo, in miniature."""
    loss_fn, eval_fn, p0, data = golden_problem
    base = dict(algo="stl_sc", eta1=0.5, T1=16, k1=2.0, n_stages=4,
                iid=True, batch_per_client=8, seed=0)
    h_flat = simulate.run(loss_fn, p0, data, TrainConfig(**base), eval_fn,
                          eval_every=8)
    cfg_h = TrainConfig(topology="hier", n_pods=2, inter_reducer="int8",
                        **base)
    h_hier = simulate.run(loss_fn, p0, data, cfg_h, eval_fn, eval_every=8)
    assert abs(h_hier[-1].value - h_flat[-1].value) < 5e-3
    summ = topology_for(cfg_h).summary(p0, 4, h_hier[-1].round)
    assert [h["hop"] for h in summ["hops"]] == ["intra_pod", "inter_pod"]
    assert summ["hops"][0]["bandwidth_gbps"] > summ["hops"][1]["bandwidth_gbps"]


# ---------------------------------------------------------------------------
# Engine plumbing
# ---------------------------------------------------------------------------

def test_engine_requires_cost_basis():
    class BadBackend:
        def setup(self, engine):
            pass

    eng = Engine("sync", TrainConfig(algo="sync", n_stages=1))
    with pytest.raises(RuntimeError):
        eng.run(BadBackend())


def test_engine_report_ledger(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = TrainConfig(algo="local", eta1=0.3, T1=8, k1=2.0, n_stages=2,
                      iid=True, batch_per_client=8, seed=0)
    eng = Engine(cfg.algo, cfg)
    backend = simulate.VmapSimulatorBackend(loss_fn, p0, data, eval_fn,
                                            eval_every=4)
    hist = eng.run(backend)
    assert eng.report.rounds_total == hist[-1].round == 8
    assert eng.report.iters_total == hist[-1].iteration == 16
    assert eng.report.stages_run == 2
    summ = comm_summary_for(cfg, p0, 4, 8)
    assert eng.report.comm_bytes_total == summ["total_bytes"]
    assert eng.report.comm_time_s == pytest.approx(summ["total_time_s"])
