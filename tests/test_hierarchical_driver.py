"""Two-level (hierarchical) sync rounds in the pjit driver.

The decisive invariants (ISSUE 5 acceptance):
  * flat safety rail — with ``n_pods=1`` or a dense inter reducer the
    two-level round is bit-exact with the existing flat round (params,
    opt, and state key set);
  * shared code path — the driver's two-level round IS
    ``engine.Hierarchical.reduce`` (the reduce the vmapped simulator
    executes), so a multi-round driver trace with int8-EF WAN is
    bit-exact with the topology-level replay on the same seed, error
    feedback residuals included;
  * ledger honesty — ``StagewiseDriver`` prices a hierarchical run
    through ``engine.Hierarchical``: the per-(leaf, hop) ledger carries
    two hops per leaf and reconciles bit-exactly (bytes; modeled seconds
    to float-sum precision) with both the run totals and the tree-level
    ``round_bytes``/``round_time``;
  * tag discipline — config and sync-step tags must agree: a flat step
    under a hierarchical config, mismatched n_pods, or streaming+
    hierarchical are refused with actionable errors;
  * mesh structure — on a (pod, data, model) mesh the two-level round's
    collectives split into data-axis-only (intra-pod) and pod-axis-only
    (inter-pod) traffic, where the flat round moves everything across
    the combined pod+data group (subprocess, 8 host devices).
"""
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import DenseMean, QuantizedMean, get_reducer
from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core.stl_sgd import StagewiseDriver
from repro.engine import Hierarchical, topology_for
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading

N_CLIENTS, N_PODS = 4, 2  # the 2-pod × 2-client grid


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _state(n=N_CLIENTS, d=12, seed=0, perturb=True):
    key = jax.random.key(seed)
    params = {"w1": jax.random.normal(key, (d, d)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (d,))}
    state = {"params": tree_broadcast_leading(params, n),
             "opt": {"mu": jax.tree.map(
                 jnp.zeros_like, tree_broadcast_leading(params, n))},
             "step": jnp.zeros((), jnp.int32)}
    if perturb:  # give every client its own replica so the round works
        state["params"] = jax.tree.map(
            lambda x: x + 0.01 * jax.random.normal(
                jax.random.fold_in(key, x.shape[-1]), x.shape),
            state["params"])
    return state


def _drift(state, eta=0.1):
    """Deterministic per-client local step (signature-compatible toy)."""
    params = jax.tree.map(
        lambda x: x * (1.0 - 0.01 * eta)
        + 0.001 * jnp.arange(x.shape[0], dtype=x.dtype).reshape(
            (x.shape[0],) + (1,) * (x.ndim - 1)),
        state["params"])
    return dict(state, params=params, step=state["step"] + 1)


def _toy_train_step(state, batch, eta):
    return _drift(state, eta), {"loss": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Flat safety rail: n_pods=1 and dense∘dense collapse bit-exactly
# ---------------------------------------------------------------------------

def test_two_level_dense_wan_bit_exact_with_flat_round():
    state = _state()
    flat = jax.jit(LS.build_sync_step(None))
    hier = jax.jit(LS.build_sync_step(None, hierarchical=True,
                                      n_pods=N_PODS, inter_reducer="dense"))
    out_f, out_h = flat(state), hier(state)
    assert set(out_f.keys()) == set(out_h.keys())  # no stray comm state
    _tree_equal(out_f, out_h)


def test_two_level_single_pod_bit_exact_with_flat_round():
    """One pod has no inter-pod link: the round degenerates to the flat
    round with the intra reducer, inter reducer unused."""
    state = _state()
    flat = jax.jit(LS.build_sync_step(None))
    hier = jax.jit(LS.build_sync_step(None, hierarchical=True, n_pods=1,
                                      inter_reducer="int8"))
    _tree_equal(flat(state), hier(state))
    assert LS.build_sync_step(None, hierarchical=True, n_pods=1).hierarchical \
        is False


def test_hierarchical_dense_dense_collapses_to_flat_mean():
    """Topology level: dense∘dense is computed AS the flat mean (bit-exact,
    not merely allclose) — the contract the driver's rail relies on."""
    stacked = _state(n=8)["params"]
    topo = Hierarchical(n_pods=2, intra=DenseMean(), inter=DenseMean())
    assert topo.all_dense
    mean, _ = topo.reduce(stacked, topo.init_state(stacked),
                          jax.random.key(1))
    _tree_equal(mean, tree_mean_leading(stacked))
    assert not Hierarchical(n_pods=2, inter=QuantizedMean()).all_dense


def test_two_level_rejects_indivisible_clients():
    sync = LS.build_sync_step(None, hierarchical=True, n_pods=N_PODS)
    with pytest.raises(ValueError, match="divisible"):
        sync(_state(n=5))


# ---------------------------------------------------------------------------
# Shared code path: driver round ≡ engine.Hierarchical.reduce (same seed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("inter", ["dense", "int8"])
def test_driver_trace_bit_exact_with_hierarchical_replay(inter):
    """2-pod × 2-client trace: StagewiseDriver with the two-level sync step
    vs a replay of the same schedule through ``Hierarchical.reduce`` (the
    simulator's hierarchical round) with the driver's rng rule — params and
    EF state bit-identical after every stage."""
    tcfg = TrainConfig(algo="local", T1=8, k1=2.0, n_stages=2,
                       topology="hier", n_pods=N_PODS, inter_reducer=inter)
    sync_step = LS.build_sync_step(None, hierarchical=True, n_pods=N_PODS,
                                   inter_reducer=inter)
    drv = StagewiseDriver(tcfg, _toy_train_step, sync_step)
    assert drv.hierarchical and drv.n_pods == N_PODS
    ds = drv.run(_state(), iter([None] * 256))

    # replay: same stage stream, same drift, sync via the topology the
    # simulator executes, rng = fold_in(key(base_seed=0), step)
    topo = Hierarchical(n_pods=N_PODS, intra=get_reducer(None),
                        inter=get_reducer(inter))
    state, comm = _state(), None
    rounds = 0
    for stage in drv.stages:
        done = 0
        while done < stage.T:
            for _ in range(min(stage.k, stage.T - done)):
                state = _drift(state, stage.eta)
                done += 1
            rng = jax.random.fold_in(jax.random.key(0), state["step"])
            if topo.all_dense:
                consensus, _ = topo.reduce(state["params"], None, rng)
            else:
                if comm is None:
                    comm = topo.init_state(state["params"])
                consensus, comm = topo.reduce(state["params"], comm, rng)
            state = dict(state, params=tree_broadcast_leading(
                consensus, N_CLIENTS))
            rounds += 1
    assert ds.rounds_total == rounds
    _tree_equal(ds.state["params"], state["params"])
    if inter != "dense":
        _tree_equal(ds.state["comm"], comm)
    else:
        assert "comm" not in ds.state  # flat contract: state untouched


# ---------------------------------------------------------------------------
# Ledger: two hops per leaf, reconciled against tree totals
# ---------------------------------------------------------------------------

def test_driver_hierarchical_leaf_ledger_reconciles():
    tcfg = TrainConfig(algo="local", T1=8, k1=2.0, n_stages=1,
                       topology="hier", n_pods=N_PODS, inter_reducer="int8")
    sync_step = LS.build_sync_step(None, hierarchical=True, n_pods=N_PODS,
                                   inter_reducer="int8")
    drv = StagewiseDriver(tcfg, _toy_train_step, sync_step)
    ds = drv.run(_state(), iter([None] * 64))
    assert ds.rounds_total == 4
    template = jax.tree.map(lambda x: x[0], _state()["params"])
    n_leaves = len(jax.tree.leaves(template))
    assert len(ds.leaf_ledger) == 2 * n_leaves
    assert {l["hop"] for l in ds.leaf_ledger} == {"intra_pod", "inter_pod"}
    # per-leaf totals reconcile with the run totals (bytes bit-exactly,
    # modeled seconds to float-sum precision) ...
    assert sum(l["bytes"] for l in ds.leaf_ledger) == ds.comm_bytes_total
    assert math.fsum(l["time_s"] for l in ds.leaf_ledger) \
        == pytest.approx(ds.comm_time_s, rel=1e-12)
    # ... and the run totals with the Hierarchical tree-level price of the
    # config's topology (the modeled-vs-executed byte agreement)
    topo = topology_for(tcfg)
    assert isinstance(topo, Hierarchical)
    assert ds.comm_bytes_total \
        == topo.round_bytes(template, N_CLIENTS) * ds.rounds_total
    intra = sum(l["bytes"] for l in ds.leaf_ledger
                if l["hop"] == "intra_pod")
    hop_bytes = {h.hop: h.bytes for h in topo.hop_costs(template, N_CLIENTS)}
    assert intra == hop_bytes["intra_pod"] * ds.rounds_total


# ---------------------------------------------------------------------------
# Tag discipline: config and sync step must describe the same round
# ---------------------------------------------------------------------------

def test_driver_refuses_flat_step_under_hierarchical_config():
    tcfg = TrainConfig(algo="local", topology="hier", n_pods=N_PODS)
    with pytest.raises(ValueError, match="build_sync_step"):
        StagewiseDriver(tcfg, _toy_train_step, LS.build_sync_step(None))


def test_driver_refuses_n_pods_mismatch():
    tcfg = TrainConfig(algo="local", topology="hier", n_pods=4)
    sync = LS.build_sync_step(None, hierarchical=True, n_pods=N_PODS)
    with pytest.raises(ValueError, match="n_pods"):
        StagewiseDriver(tcfg, _toy_train_step, sync)


def test_driver_refuses_inter_reducer_mismatch():
    """cfg-derived reports (comm_summary_for) and the executed ledger must
    price the same WAN hop — a dense-vs-int8 mismatch would silently
    diverge modeled from executed bytes."""
    tcfg = TrainConfig(algo="local", topology="hier", n_pods=N_PODS,
                       inter_reducer="dense")
    sync = LS.build_sync_step(None, hierarchical=True, n_pods=N_PODS,
                              inter_reducer="int8")
    with pytest.raises(ValueError, match="inter_reducer"):
        StagewiseDriver(tcfg, _toy_train_step, sync)


def test_hier_tagged_step_implies_hierarchical_under_star_config():
    """Mirror of the streaming-tag rule: the executed round wins, and the
    ledger follows it (jit-wrapped tags included)."""
    sync = jax.jit(LS.build_sync_step(None, hierarchical=True,
                                      n_pods=N_PODS, inter_reducer="int8"))
    drv = StagewiseDriver(TrainConfig(algo="local", T1=4, k1=2.0,
                                      n_stages=1), _toy_train_step, sync)
    assert drv.hierarchical and drv.n_pods == N_PODS
    assert drv.inter_reducer.name == "int8"
    ds = drv.run(_state(), iter([None] * 32))
    assert {l["hop"] for l in ds.leaf_ledger} == {"intra_pod", "inter_pod"}


def test_single_pod_config_runs_flat():
    """n_pods=1 under topology='hier' is the flat degenerate case — both
    the sync step and the pricing fall back to the star round."""
    tcfg = TrainConfig(algo="local", T1=4, k1=2.0, n_stages=1,
                       topology="hier", n_pods=1)
    sync = LS.build_sync_step(None, hierarchical=True, n_pods=1)
    drv = StagewiseDriver(tcfg, _toy_train_step, sync)
    assert not drv.hierarchical
    ds = drv.run(_state(), iter([None] * 32))
    assert {l["hop"] for l in ds.leaf_ledger} == {"uplink"}


def test_streaming_hierarchical_driver_composes():
    """streaming=True now composes with hierarchical=True: the driver runs
    the per-leaf two-level round, bit-exact with the blocking one, under
    the streaming-hier topology spec, pricing both hops."""
    sync_b = LS.build_sync_step("int8", hierarchical=True,
                                n_pods=N_PODS, inter_reducer="int8")
    sync_s = LS.build_sync_step("int8", streaming=True,
                                hierarchical=True, n_pods=N_PODS,
                                inter_reducer="int8")
    assert sync_s.streaming and sync_s.hierarchical
    cfg = dict(algo="local", T1=4, k1=2.0, n_stages=1, reducer="int8")
    drv_b = StagewiseDriver(TrainConfig(**cfg, topology="hier",
                                        n_pods=N_PODS), _toy_train_step,
                            sync_b)
    drv_s = StagewiseDriver(TrainConfig(**cfg, topology="streaming-hier",
                                        n_pods=N_PODS), _toy_train_step,
                            sync_s)
    assert drv_s.streaming and drv_s.hierarchical
    assert drv_s.build_topology().name == "streaming-hier"
    ds_b = drv_b.run(_state(), iter([None] * 32))
    ds_s = drv_s.run(_state(), iter([None] * 32))
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()),
        ds_b.state["params"], ds_s.state["params"]))
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((a == b).all()),
        ds_b.state["comm"], ds_s.state["comm"]))
    # the streaming ledger prices the identical two-level round
    assert ds_s.comm_bytes_total == ds_b.comm_bytes_total
    assert {l["hop"] for l in ds_s.leaf_ledger} == {"intra_pod", "inter_pod"}
    assert sum(l["bytes"] for l in ds_s.leaf_ledger) == ds_s.comm_bytes_total


def test_build_train_steps_two_level_needs_pod_axis():
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError, match="pod"):
        LS.build_train_steps(get_arch("qwen3-14b", smoke=True),
                             make_host_mesh(1, 1), client_axis="data",
                             inter_reducer="int8")


# ---------------------------------------------------------------------------
# Mesh structure: intra hop on the data axis, inter hop on the pod axis
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "@SRC@")
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import local_sgd as LS
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_host_pod_mesh, mesh_context

mesh = make_host_pod_mesh(pods=2, data=2, model=2)
C = 4
key = jax.random.key(0)
params = {"w1": jax.random.normal(key, (C, 32, 8)),
          "w2": jax.random.normal(jax.random.fold_in(key, 1), (C, 8))}
state = {"params": params,
         "opt": {"mu": jax.tree.map(jnp.zeros_like, params)},
         "step": jnp.zeros((), jnp.int32)}
rep = NamedSharding(mesh, P(("pod", "data")))
st_sh = {"params": jax.tree.map(lambda _: rep, params),
         "opt": {"mu": jax.tree.map(lambda _: rep, params)},
         "step": NamedSharding(mesh, P())}
shape_d = dict(zip(mesh.axis_names, mesh.devices.shape))
out = {}
with mesh_context(mesh):
    for name, step in [
            ("flat", LS.build_sync_step(None)),
            ("hier", LS.build_sync_step(None, hierarchical=True, n_pods=2,
                                        inter_reducer="int8"))]:
        compiled = jax.jit(step, in_shardings=(st_sh,)).lower(state).compile()
        colls = H.parse_collectives_nested(compiled.as_text(), shape_d)
        out[name] = H.collective_summary(colls)["by_axes"]
print(json.dumps(out))
"""


_TRAIN_STEPS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "@SRC@")
import dataclasses, jax, json
from repro.configs import get_arch, SHAPES
from repro.core import local_sgd as LS
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_host_pod_mesh, mesh_context
from repro.launch.specs import train_specs

mesh = make_host_pod_mesh(pods=2, data=2, model=2)
cfg = get_arch("qwen3-14b", smoke=True)
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
state, batch, st_sh, b_sh, ca = train_specs(cfg, shape, mesh)
assert tuple(ca) == ("pod", "data"), ca
with mesh_context(mesh):
    local_step, sync_step, _ = LS.build_train_steps(
        cfg, mesh, client_axis=ca, microbatch=1, inter_reducer="int8")
    assert sync_step.hierarchical and sync_step.n_pods == 2
    assert sync_step.inter_reducer.name == "int8"
    cl = jax.jit(local_step, in_shardings=(st_sh, b_sh, None),
                 out_shardings=(st_sh, None)).lower(state, batch,
                                                    0.1).compile()
    cs = jax.jit(sync_step, in_shardings=(st_sh,)).lower(state).compile()
shape_d = dict(zip(mesh.axis_names, mesh.devices.shape))
out = {n: H.collective_summary(
           H.parse_collectives_nested(c.as_text(), shape_d))["by_axes"]
       for n, c in [("local", cl), ("sync", cs)]}
print(json.dumps(out))
"""


def test_build_train_steps_two_level_positive_path():
    """The advertised entry point — ``build_train_steps(client_axis=
    ("pod", "data"), inter_reducer=...)`` on a real multi-pod mesh —
    lowers and compiles end-to-end: the tuple-spmd local step keeps the
    client grid collective-free, the derived sync step is two-level
    (intra traffic on data, inter traffic on pod)."""
    script = _TRAIN_STEPS_SCRIPT.replace("@SRC@", os.path.abspath(SRC))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # local step: client-grid traffic is control-plane only (loss scalars)
    client_bytes = sum(v for k, v in res["local"].items()
                       if "pod" in k or "data" in k)
    assert client_bytes < 1e5, res["local"]
    # sync step: real two-level traffic, split by axis
    assert sum(v for k, v in res["sync"].items() if k == "data") > 1e5, \
        res["sync"]
    assert sum(v for k, v in res["sync"].items() if k == "pod") > 0, \
        res["sync"]


def test_two_level_sync_collectives_split_by_mesh_axis():
    """Compile both sync rounds on a (pod=2, data=2, model=2) host mesh:
    the two-level round must move intra-pod traffic on the data axis and
    inter-pod traffic on the pod axis as *separate* collective groups; the
    flat round has no pod-only reduction (everything crosses the combined
    client group)."""
    script = _MESH_SCRIPT.replace("@SRC@", os.path.abspath(SRC))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    hier, flat = res["hier"], res["flat"]
    data_only = sum(v for k, v in hier.items() if k == "data")
    pod_only = sum(v for k, v in hier.items() if k == "pod")
    assert data_only > 0, hier    # intra-pod reduce rides the data axis
    assert pod_only > 0, hier     # inter-pod hop rides the pod axis
    assert sum(v for k, v in flat.items() if k == "pod") == 0, flat
    # the flat round's client average spans pod+data as one group
    assert sum(v for k, v in flat.items() if "pod" in k and "data" in k) > 0, \
        flat
