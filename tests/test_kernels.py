"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps with assert_allclose, plus gradient checks through the custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_update.ops import sgd_update, tree_sgd_update
from repro.kernels.fused_update.ref import sgd_update_ref


def _qkv(B, S, H, KV, D, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 4, 2, 64),    # GQA
    (1, 256, 8, 1, 128),   # MQA, MXU-width head
    (2, 512, 2, 2, 128),   # longer sequence
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    B, S, H, KV, D = shape
    q, k, v = _qkv(B, S, H, KV, D, dtype)
    out = flash_attention(q, k, v, impl="interpret")
    ref = attention_ref(q, k, v).astype(out.dtype)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_masks(window, softcap):
    q, k, v = _qkv(1, 256, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, window=window, softcap=softcap,
                          impl="interpret")
    ref = attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_flash_attention_noncausal():
    q, k, v = _qkv(1, 128, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, impl="interpret")
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_attention_block_shapes():
    q, k, v = _qkv(1, 512, 2, 2, 64, jnp.float32)
    ref = attention_ref(q, k, v)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        out = flash_attention.__wrapped__ if False else None
        from repro.kernels.flash_attention.kernel import flash_attention as K
        out = K(q, k, v, block_q=bq, block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_attention_grad_matches_ref_grad():
    q, k, v = _qkv(1, 128, 2, 2, 64, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, impl="interpret") ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v).astype(q.dtype) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("n", [100, 128, 65536, 70000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("beta,wd", [(0.0, 0.0), (0.9, 0.0), (0.9, 0.01)])
def test_fused_update_matches_ref(n, dtype, beta, wd):
    ks = jax.random.split(jax.random.key(0), 3)
    p = jax.random.normal(ks[0], (n,), jnp.float32).astype(dtype)
    m = jax.random.normal(ks[1], (n,), jnp.float32)
    g = jax.random.normal(ks[2], (n,), jnp.float32).astype(dtype)
    p2, m2 = sgd_update(p, m, g, eta=0.1, beta=beta, wd=wd, impl="interpret")
    pr, mr = sgd_update_ref(p, m, g, eta=0.1, beta=beta, wd=wd)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(p2, np.float32),
                               np.asarray(pr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(m2, np.float32),
                               np.asarray(mr, np.float32), atol=tol, rtol=tol)


def test_fused_update_tree():
    params = {"a": jnp.ones((37, 5)), "b": jnp.full((256,), 2.0)}
    moms = {"a": jnp.zeros((37, 5)), "b": jnp.zeros((256,))}
    grads = {"a": jnp.full((37, 5), 0.5), "b": jnp.ones((256,))}
    p2, m2 = tree_sgd_update(params, moms, grads, eta=0.1, impl="interpret")
    np.testing.assert_allclose(np.asarray(p2["a"]), 1.0 - 0.05, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p2["b"]), 2.0 - 0.1, rtol=1e-6)
