"""Algorithm-1 semantics of the distributed step builders.

The decisive invariants:
  * k = 1 Local SGD ≡ SyncSGD bit-for-bit (moments averaged at sync),
  * local steps never mix client state (client i's params independent of
    client j's data),
  * averaging round equals the explicit mean of replicas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import local_sgd as LS
from repro.launch.mesh import make_host_mesh
from repro.utils.tree import tree_allclose, tree_mean_leading


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen3-14b", smoke=True).replace(dtype="float32")
    mesh = make_host_mesh(1, 1)
    C, B, S = 4, 2, 32
    state = LS.init_state(jax.random.key(0), cfg, C)
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (C, B, S)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (C, B, S)), jnp.int32),
    }
    return cfg, mesh, state, batch


def test_k1_local_equals_syncsgd(setup):
    cfg, mesh, state, batch = setup
    local_step, sync_step, _ = LS.build_train_steps(cfg, mesh, client_axis="data")
    syncsgd_step, _, _ = LS.build_train_steps(cfg, mesh, client_axis="data",
                                              sync_grads=True)
    # one local step + averaging round
    s_local, _ = jax.jit(local_step)(state, batch, 0.05)
    s_local = jax.jit(sync_step)(s_local)
    # one SyncSGD step (identical init params across clients)
    s_sync, _ = jax.jit(syncsgd_step)(state, batch, 0.05)

    for a, b in zip(jax.tree.leaves(s_local["params"]),
                    jax.tree.leaves(s_sync["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-6)


def test_local_step_client_independence(setup):
    cfg, mesh, state, batch = setup
    local_step, _, _ = LS.build_train_steps(cfg, mesh, client_axis="data")
    s1, _ = jax.jit(local_step)(state, batch, 0.05)

    # perturb client 3's data only — clients 0-2 must be unaffected
    batch2 = jax.tree.map(lambda x: x.copy(), batch)
    batch2["tokens"] = batch2["tokens"].at[3].set(
        (batch2["tokens"][3] + 7) % cfg.vocab_size)
    s2, _ = jax.jit(local_step)(state, batch2, 0.05)

    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a[:3]), np.asarray(b[:3]))
    # and client 3 must differ somewhere
    diff = any(
        not np.array_equal(np.asarray(a[3]), np.asarray(b[3]))
        for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])))
    assert diff


def test_sync_step_is_replica_mean(setup):
    cfg, mesh, state, batch = setup
    local_step, sync_step, _ = LS.build_train_steps(cfg, mesh, client_axis="data")
    s, _ = jax.jit(local_step)(state, batch, 0.05)  # make replicas diverge
    mean = tree_mean_leading(s["params"])
    s2 = jax.jit(sync_step)(s)
    for m, leaf in zip(jax.tree.leaves(mean), jax.tree.leaves(s2["params"])):
        for i in range(leaf.shape[0]):
            np.testing.assert_allclose(np.asarray(leaf[i]), np.asarray(m),
                                       rtol=1e-6, atol=1e-7)


def test_microbatch_grad_equivalence(setup):
    cfg, mesh, state, batch = setup
    s_full, m_full = jax.jit(
        LS.build_train_steps(cfg, mesh, client_axis="data", microbatch=1)[0]
    )(state, batch, 0.05)
    s_mb, m_mb = jax.jit(
        LS.build_train_steps(cfg, mesh, client_axis="data", microbatch=2)[0]
    )(state, batch, 0.05)
    assert m_full["loss"] == pytest.approx(float(m_mb["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s_full["params"]), jax.tree.leaves(s_mb["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-4, atol=1e-5)
