"""The topology × schedule × reducer conformance matrix.

Every cell of {star, streaming-star, hier, streaming-hier} ×
{blocking, streaming} × {dense, int8, topk} is exercised on all three
execution surfaces — the vmapped simulator (pure numerics), the
StagewiseDriver (executed collectives + priced ledger), and the event
runtime (numerics + modeled clock) — with downlink billing on.  No
cell is refused.  Supported-cell invariants:

  * the schedule axis is pure clock accounting: blocking and streaming
    schedules produce bit-identical params and (round, objective)
    histories, and the streaming clock never loses;
  * the topology streaming variants are pure scheduling too:
    StreamingStar ≡ Star and Hierarchical(streaming=True) ≡
    Hierarchical bit-exactly, error-feedback state included;
  * the dense column collapses: every topology degenerates to the flat
    star mean bit-exactly;
  * the per-(leaf, hop) ledger — uplink, intra/inter-pod, downlink —
    reconciles with the tree-level totals in every cell (bytes
    bit-exactly, modeled seconds to float-sum precision).

Combinations outside the matrix stay refused with actionable error
text, pinned here: asynchronous merging × {streaming schedules,
non-star topologies, downlink billing}, per-leaf schedules over
reducers without per-leaf payload accounting, and flat sync steps
under hierarchical driver configs.  The capability probe
(``supports_leaf_bytes``) is a regression target of its own: an
*implemented but raising* ``leaf_message_bytes`` must propagate, never
silently degrade to monolithic blob pricing.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.comm import (
    DenseMean,
    NetworkModel,
    Reducer,
    get_reducer,
    supports_leaf_bytes,
)
from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core import simulate
from repro.core.stl_sgd import StagewiseDriver
from repro.data import make_binary_classification, partition_iid
from repro.engine import Hierarchical, Star, StreamingStar, get_topology
from repro.models import mlp
from repro.runtime import BlockingSchedule, ClientProcess, StreamingSchedule
from repro.runtime.schedule import get_schedule
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading

REDUCERS = ["dense", "int8", "topk"]
TOPOLOGIES = ["star", "streaming", "hier", "streaming-hier"]
SCHEDULES = ["blocking", "streaming"]
N_CLIENTS, N_PODS = 4, 2


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _hist(res):
    return [(h.round, h.iteration, h.value) for h in res.history]


# ---------------------------------------------------------------------------
# Event-runtime cells (lazy, cached across tests in this module)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    x, y = make_binary_classification(n=256, d=32, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, N_CLIENTS, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: mlp.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: mlp.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, mlp.init_params(jax.random.key(7), 32), data


def _cell_cfg(topology, schedule, reducer, **kw):
    base = dict(algo="local", eta1=0.1, T1=8, k1=2.0, n_stages=1,
                batch_per_client=8, seed=0,
                reducer=reducer, inter_reducer=reducer,
                topology=topology, n_pods=N_PODS,
                upload_schedule=schedule, count_downlink=True,
                comm_latency_s=1e-4, comm_bandwidth_gbps=0.45,
                base_step_time_s=1e-3,
                straggler_frac=0.25, straggler_slowdown=2.0)
    base.update(kw)
    return TrainConfig(**base)


_RUNS = {}


def _run(problem, topology, schedule, reducer):
    key = (topology, schedule, reducer)
    if key not in _RUNS:
        loss_fn, eval_fn, p0, data = problem
        _RUNS[key] = runtime.run(
            loss_fn, p0, data, _cell_cfg(topology, schedule, reducer),
            eval_fn, eval_every=2)
    return _RUNS[key]


@pytest.mark.parametrize("reducer", REDUCERS)
def test_matrix_event_backend(problem, reducer):
    runs = {(t, s): _run(problem, t, s, reducer)
            for t in TOPOLOGIES for s in SCHEDULES}
    # no cell refused, every cell ran its full round budget on the clock
    for r in runs.values():
        assert r.rounds == 4 and r.wall_clock_s > 0.0

    # schedule axis is pure clock: identical numerics, clock never loses
    for t in TOPOLOGIES:
        blk, stm = runs[(t, "blocking")], runs[(t, "streaming")]
        assert _hist(blk) == _hist(stm)
        _tree_equal(blk.params, stm.params)
        assert stm.wall_clock_s <= blk.wall_clock_s
        # the engine ledger (serial α–β view) is schedule-independent
        assert stm.comm_bytes == blk.comm_bytes
        assert stm.comm_time_s == blk.comm_time_s

    # topology streaming variants are pure scheduling: bit-exact numerics
    for base, stream in (("star", "streaming"), ("hier", "streaming-hier")):
        for s in SCHEDULES:
            assert _hist(runs[(base, s)]) == _hist(runs[(stream, s)])
            _tree_equal(runs[(base, s)].params, runs[(stream, s)].params)

    # dense column: every topology collapses to the flat star mean
    if reducer == "dense":
        ref = runs[("star", "blocking")]
        for cell, r in runs.items():
            assert _hist(r) == _hist(ref), cell
            _tree_equal(r.params, ref.params)

    # per-(leaf, hop) ledger reconciles in every cell, downlink included
    n_leaves = len(jax.tree.leaves(problem[2]))
    for (t, s), r in runs.items():
        assert r.leaf_ledger, (t, s)
        hops = {l["hop"] for l in r.leaf_ledger}
        if t in ("star", "streaming"):
            assert hops == {"uplink", "downlink"}
            assert len(r.leaf_ledger) == 2 * n_leaves
        else:
            assert hops == {"intra_pod", "inter_pod", "downlink"}
            assert len(r.leaf_ledger) == 3 * n_leaves
        assert sum(l["bytes"] for l in r.leaf_ledger) == r.comm_bytes
        assert math.fsum(l["time_s"] for l in r.leaf_ledger) \
            == pytest.approx(r.comm_time_s, rel=1e-12)

    # ≥ 4 leaves overlap under 2× stragglers: the flat streaming cell must
    # strictly beat blocking, not just tie
    assert n_leaves >= 4
    assert runs[("star", "streaming")].wall_clock_s \
        < runs[("star", "blocking")].wall_clock_s


def test_wan_streaming_compounds_the_overlap(problem):
    """streaming∘hierarchical: streaming only the uplink (the PR-4
    comparator) already beats blocking; streaming the WAN hop and the
    downlink too compounds the win — all three bit-exact in params."""
    loss_fn, eval_fn, p0, data = problem
    blk = _run(problem, "streaming-hier", "blocking", "int8")
    full = _run(problem, "streaming-hier", "streaming", "int8")
    up = runtime.run(
        loss_fn, p0, data,
        _cell_cfg("streaming-hier", "streaming-uplink", "int8"),
        eval_fn, eval_every=2)
    assert _hist(blk) == _hist(up) == _hist(full)
    _tree_equal(blk.params, up.params)
    _tree_equal(blk.params, full.params)
    assert up.wall_clock_s < blk.wall_clock_s
    assert full.wall_clock_s < up.wall_clock_s


@pytest.mark.parametrize("reducer", REDUCERS)
@pytest.mark.parametrize("topology", TOPOLOGIES)
def test_matrix_simulator_agrees_with_event_backend(problem, topology,
                                                    reducer):
    """The vmapped simulator runs every topology cell and lands on the
    event backend's trajectory exactly (heterogeneity is pure clock)."""
    loss_fn, eval_fn, p0, data = problem
    h_sim = simulate.run(loss_fn, p0, data,
                         _cell_cfg(topology, "blocking", reducer),
                         eval_fn, eval_every=2)
    got = [(h.round, h.iteration, h.value) for h in h_sim]
    assert got == _hist(_run(problem, topology, "blocking", reducer))


# ---------------------------------------------------------------------------
# Topology.reduce cells: consensus AND reducer state bit-exact
# ---------------------------------------------------------------------------

def _stacked(seed=0):
    key = jax.random.key(seed)
    return {"a": jax.random.normal(key, (N_CLIENTS, 17)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (N_CLIENTS, 3, 5)),
                  "d": jax.random.normal(jax.random.fold_in(key, 2),
                                         (N_CLIENTS, 9))}}


@pytest.mark.parametrize("reducer", REDUCERS)
def test_matrix_topology_reduce_bit_exact(reducer):
    """Two evolving rounds through each topology: the streaming variants
    match their blocking bases bit-exactly, error-feedback state
    included; the dense column collapses to the flat mean."""
    topos = {
        "star": Star(reducer=get_reducer(reducer)),
        "streaming": StreamingStar(reducer=get_reducer(reducer)),
        "hier": Hierarchical(n_pods=N_PODS, intra=get_reducer(reducer),
                             inter=get_reducer(reducer)),
        "streaming-hier": Hierarchical(n_pods=N_PODS,
                                       intra=get_reducer(reducer),
                                       inter=get_reducer(reducer),
                                       streaming=True),
    }
    stacked = _stacked()
    states = {k: t.init_state(stacked) for k, t in topos.items()}
    outs = {}
    for rnd in range(2):
        rng = jax.random.fold_in(jax.random.key(3), rnd)
        for k, t in topos.items():
            outs[k], states[k] = t.reduce(stacked, states[k], rng)
        # evolve the replicas so round 2 exercises threaded EF state
        stacked = jax.tree.map(lambda x: 0.9 * x, stacked)
        for base, stream in (("star", "streaming"),
                             ("hier", "streaming-hier")):
            _tree_equal(outs[base], outs[stream])
            _tree_equal(states[base], states[stream])
        if reducer == "dense":
            for k in topos:
                _tree_equal(outs[k], outs["star"])


# ---------------------------------------------------------------------------
# StagewiseDriver cells: executed collectives + priced ledger
# ---------------------------------------------------------------------------

def _driver_state(n=N_CLIENTS, d=12, seed=0):
    key = jax.random.key(seed)
    params = {"w1": jax.random.normal(key, (d, d)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (d,))}
    state = {"params": tree_broadcast_leading(params, n),
             "opt": {"mu": jax.tree.map(
                 jnp.zeros_like, tree_broadcast_leading(params, n))},
             "step": jnp.zeros((), jnp.int32)}
    state["params"] = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(
            jax.random.fold_in(key, x.shape[-1]), x.shape),
        state["params"])
    return state


def _toy_train_step(state, batch, eta):
    params = jax.tree.map(lambda x: x * (1.0 - 0.01 * eta), state["params"])
    return dict(state, params=params, step=state["step"] + 1), \
        {"loss": jnp.zeros(())}


def _driver_cell(topology, reducer):
    red = None if reducer == "dense" else reducer
    hier = topology in ("hier", "streaming-hier")
    streaming = topology in ("streaming", "streaming-hier")
    sync = LS.build_sync_step(red, streaming=streaming, hierarchical=hier,
                              n_pods=N_PODS, inter_reducer=red or "dense")
    tcfg = TrainConfig(algo="local", T1=8, k1=2.0, n_stages=1,
                       reducer=reducer, inter_reducer=reducer,
                       topology=topology, n_pods=N_PODS,
                       count_downlink=True)
    drv = StagewiseDriver(tcfg, _toy_train_step, sync)
    assert drv.streaming == streaming and drv.hierarchical == hier
    return drv.run(_driver_state(), iter([None] * 64))


@pytest.mark.parametrize("reducer", REDUCERS)
def test_matrix_driver(reducer):
    runs = {t: _driver_cell(t, reducer) for t in TOPOLOGIES}
    for ds in runs.values():
        assert ds.rounds_total == 4
    # streaming variants execute the identical round (params + EF state)
    for base, stream in (("star", "streaming"), ("hier", "streaming-hier")):
        _tree_equal(runs[base].state["params"], runs[stream].state["params"])
        if reducer != "dense":
            _tree_equal(runs[base].state["comm"], runs[stream].state["comm"])
        assert runs[base].comm_bytes_total == runs[stream].comm_bytes_total
    # dense column collapses to the flat star round
    if reducer == "dense":
        for t in TOPOLOGIES:
            _tree_equal(runs[t].state["params"], runs["star"].state["params"])
    # the priced per-(leaf, hop) ledger reconciles, downlink included
    for t, ds in runs.items():
        hops = {l["hop"] for l in ds.leaf_ledger}
        if t in ("star", "streaming"):
            assert hops == {"uplink", "downlink"}
        else:
            assert hops == {"intra_pod", "inter_pod", "downlink"}
        assert sum(l["bytes"] for l in ds.leaf_ledger) == ds.comm_bytes_total
        assert math.fsum(l["time_s"] for l in ds.leaf_ledger) \
            == pytest.approx(ds.comm_time_s, rel=1e-12)


# ---------------------------------------------------------------------------
# Downlink schedule arithmetic (fixed examples; hypothesis versions of the
# tiling/partition laws live in tests/test_property.py)
# ---------------------------------------------------------------------------

def _client(count_downlink, alpha=1e-4, gbps=0.8):
    return ClientProcess(cid=0, rate=1.0, step_time_s=1e-3,
                         network=NetworkModel(latency_s=alpha,
                                              bandwidth_gbps=gbps,
                                              count_downlink=count_downlink))


def test_blocking_broadcast_events():
    # unbilled downlink: the consensus lands free and instantly at merge
    evs, ready = BlockingSchedule().broadcast_events(
        _client(False), [1.0e-3, 2.0e-3], [4000, 4000])
    assert evs == [] and ready == 2.0e-3
    # billed: one monolithic broadcast after the merge, α + Σbytes/β
    evs, ready = BlockingSchedule().broadcast_events(
        _client(True), [1.0e-3, 2.0e-3], [4000, 4000])
    assert [k for _, k, _ in evs] == ["broadcast_arrival"]
    assert ready == pytest.approx(2.0e-3 + 1e-4 + 8000 / 1e8)
    assert evs[0][0] == ready


def test_streaming_broadcast_reverse_order_and_link_queue():
    """The downlink mirrors the uplink: leaf l's broadcast starts as soon
    as the server finishes reducing it (reverse-leaf order), α once, one
    serial link — so the client is ready before the blocking monolith."""
    c = _client(True)  # α 0.1 ms, 1e8 B/s
    leaf_done = [2.0e-3, 1.5e-3]  # the server reduced leaf 1 first
    evs, ready = StreamingSchedule().broadcast_events(
        c, leaf_done, [4000, 4000])
    assert [k for _, k, _ in evs] == ["leaf_broadcast", "leaf_broadcast"]
    assert [info for _, _, info in evs] == [(1,), (0,)]
    # leaf 1: 1.5 ms + α + 4000/1e8 = 1.64 ms
    assert evs[0][0] == pytest.approx(1.5e-3 + 1e-4 + 4e-5)
    # leaf 0: ready at 2.0 ms, link free at 1.64 ms -> 2.04 ms
    assert evs[1][0] == pytest.approx(2.0e-3 + 4e-5)
    assert ready == evs[1][0]
    _, ready_blk = BlockingSchedule().broadcast_events(c, leaf_done,
                                                       [4000, 4000])
    assert ready < ready_blk
    # link-bound regime: broadcasts queue back-to-back behind the stream
    evs, ready = StreamingSchedule().broadcast_events(
        c, [1.0e-3, 0.5e-3], [40000, 40000])
    assert evs[0][0] == pytest.approx(0.5e-3 + 1e-4 + 4e-4)
    assert ready == pytest.approx(evs[0][0] + 4e-4)
    # unbilled: streaming falls back to the free instant broadcast too
    evs, ready = StreamingSchedule().broadcast_events(
        _client(False), leaf_done, [4000, 4000])
    assert evs == [] and ready == 2.0e-3


def test_streaming_uplink_only_is_the_uplink_comparator():
    """StreamingSchedule(uplink_only=True) streams the uplink but keeps
    the monolithic broadcast and the serial WAN barrier — the PR-4
    behavior, kept addressable as an ablation comparator."""
    up = get_schedule("streaming-uplink")
    assert isinstance(up, StreamingSchedule) and up.uplink_only
    assert up.name == "streaming-uplink"
    assert up.streams_uplink and not up.streams_round
    full = get_schedule("streaming")
    assert full.name == "streaming"
    assert full.streams_uplink and full.streams_round
    blk = get_schedule("blocking")
    assert not blk.streams_uplink and not blk.streams_round
    # uplink-only broadcasts exactly like the blocking schedule
    c = _client(True)
    assert up.broadcast_events(c, [1.0e-3, 2.0e-3], [4000, 4000]) \
        == BlockingSchedule().broadcast_events(c, [1.0e-3, 2.0e-3],
                                               [4000, 4000])


# ---------------------------------------------------------------------------
# Capability probe: implemented-but-raising must propagate
# ---------------------------------------------------------------------------

class _LegacyMean(Reducer):
    """Pre-per-leaf-protocol reducer: only reduce/message_bytes."""
    name = "legacy"

    def reduce(self, stacked, state, rng):
        return tree_mean_leading(stacked), state

    def message_bytes(self, template):
        return sum(l.size * 4 for l in jax.tree.leaves(template))


class _BrokenLeafMean(DenseMean):
    """Per-leaf protocol *implemented* but buggy: the probe must route
    callers into the method and let the failure propagate — the old
    ``except NotImplementedError`` fallbacks silently re-priced the run
    as one monolithic blob instead."""
    name = "broken-leaf"

    def leaf_message_bytes(self, template):
        raise NotImplementedError("per-leaf accounting bug")


def test_supports_leaf_bytes_probe():
    assert not supports_leaf_bytes(_LegacyMean())
    assert supports_leaf_bytes(DenseMean())
    for spec in REDUCERS:
        assert supports_leaf_bytes(get_reducer(spec))
    # overriding counts as support even when the override raises
    assert supports_leaf_bytes(_BrokenLeafMean())


def test_raising_leaf_bytes_propagates_not_degrades():
    tmpl = {"a": jnp.zeros((8,)), "b": jnp.zeros((3, 5))}
    with pytest.raises(NotImplementedError, match="accounting bug"):
        Star(reducer=_BrokenLeafMean()).leaf_costs(tmpl, N_CLIENTS)
    with pytest.raises(NotImplementedError, match="accounting bug"):
        Hierarchical(n_pods=N_PODS, intra=_BrokenLeafMean(),
                     inter=get_reducer("int8")).leaf_costs(tmpl, N_CLIENTS)
    with pytest.raises(NotImplementedError, match="accounting bug"):
        Hierarchical(n_pods=N_PODS, intra=DenseMean(),
                     inter=_BrokenLeafMean()).leaf_costs(tmpl, N_CLIENTS)
    # the legacy (genuinely unimplemented) reducer still degrades cleanly:
    # no per-leaf rows, tree-level pricing only
    assert Star(reducer=_LegacyMean()).leaf_costs(tmpl, N_CLIENTS) == []


def test_runtime_raising_leaf_bytes_propagates(problem):
    loss_fn, eval_fn, p0, data = problem
    cfg = _cell_cfg("star", "blocking", "dense")
    with pytest.raises(NotImplementedError, match="accounting bug"):
        runtime.run(loss_fn, p0, data, cfg, eval_fn,
                    reducer=_BrokenLeafMean())


# ---------------------------------------------------------------------------
# Unsupported cells: pinned, actionable refusals
# ---------------------------------------------------------------------------

def test_refusal_async_streaming_schedule(problem):
    loss_fn, eval_fn, p0, data = problem
    cfg = _cell_cfg("star", "streaming", "dense", async_mode=True,
                    count_downlink=False)
    with pytest.raises(ValueError, match="streaming.*synchronous policy"):
        runtime.run(loss_fn, p0, data, cfg, eval_fn)


def test_refusal_async_non_star_topology(problem):
    loss_fn, eval_fn, p0, data = problem
    for topo in ("hier", "streaming", "streaming-hier"):
        cfg = _cell_cfg(topo, "blocking", "dense", async_mode=True,
                        count_downlink=False)
        with pytest.raises(ValueError, match="flat star protocol"):
            runtime.run(loss_fn, p0, data, cfg, eval_fn)


def test_refusal_async_count_downlink(problem):
    loss_fn, eval_fn, p0, data = problem
    cfg = _cell_cfg("star", "blocking", "dense", async_mode=True)
    with pytest.raises(ValueError, match="barrier rounds only"):
        runtime.run(loss_fn, p0, data, cfg, eval_fn)


def test_refusal_legacy_reducer_streaming(problem):
    loss_fn, eval_fn, p0, data = problem
    cfg = _cell_cfg("star", "streaming", "dense", count_downlink=False)
    with pytest.raises(ValueError, match="leaf_message_bytes"):
        runtime.run(loss_fn, p0, data, cfg, eval_fn, reducer=_LegacyMean())


def test_refusal_flat_step_under_hier_config():
    flat = LS.build_sync_step(None, streaming=True)
    for topo in ("hier", "streaming-hier"):
        with pytest.raises(ValueError, match="build_sync_step"):
            StagewiseDriver(
                TrainConfig(algo="local", topology=topo, n_pods=N_PODS),
                _toy_train_step, flat)


def test_refusal_unknown_specs():
    with pytest.raises(ValueError, match="unknown topology spec"):
        get_topology("bogus")
    with pytest.raises(ValueError, match="upload schedule"):
        get_schedule("bogus")
