"""repro.obs: span tracing, metrics registry, export, bench diffing.

The decisive invariants:
  * disabled tracing is free and silent: ``NULL_TRACER`` is falsy, its
    context manager is shared/no-op, and an untraced run records nothing;
  * determinism: same (config, seed) ⇒ identical span trees — including
    virtual/modeled timestamps — across repeated EventBackend runs, for
    the synchronous, streaming-upload and asynchronous regimes;
  * the trace *is* the ledger: on the modeled α–β timeline, each
    ``reduce[hop]`` span's bytes equal the bit-exact sum of its
    ``reduce_leaf`` children and its seconds their float-sum, for dense
    and int8 reducers on star, streaming and hierarchical topologies;
    on the virtual clock, streaming ``reduce_leaf`` spans sum to the
    run's ``leaf_ledger``;
  * metrics are one process-local registry: counters/gauges/histograms
    with labels, kind-checked registration, serializable snapshots that
    ``Engine.run`` copies into ``EngineReport.metrics``;
  * the Chrome-trace export is Perfetto-loadable: one process per clock
    domain, named thread rows, µs timestamps, attrs under ``args``;
  * BENCH_*.json diffing gates regressions: schema violations raise,
    a >tol increase in a monitored column regresses, scale-mismatched
    artifacts are skipped, and ``tools/bench_diff.py`` exits 0/1/2.
"""
import io
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import runtime
from repro.configs.base import TrainConfig
from repro.core import simulate
from repro.core.local_sgd import build_sync_step, sync_step_tags
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg
from repro.obs import (
    MODELED,
    NULL_TRACER,
    VIRTUAL,
    WALL,
    BenchSchemaError,
    Tracer,
    diff_benches,
    diff_dirs,
    to_chrome_trace,
    validate_bench,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs import metrics as obs_metrics
from repro.utils.logging import StructuredLogger

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _fresh_registry():
    obs_metrics.reset()
    yield
    obs_metrics.reset()


@pytest.fixture(scope="module")
def problem():
    x, y = make_binary_classification(n=256, d=16, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, logreg.init_params(None, 16), data


def _cfg(**kw):
    base = dict(algo="stl_sc", eta1=0.5, T1=16, k1=2.0, n_stages=2,
                batch_per_client=16, seed=0, base_step_time_s=1e-3)
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------

def test_null_tracer_is_falsy_and_noop():
    assert not NULL_TRACER
    assert NULL_TRACER.spans == []
    with NULL_TRACER.span("stage", attrs={"s": 1}) as sp:
        sp.set(rounds=3)                      # must be accepted and ignored
    assert NULL_TRACER.add("reduce", 0.0, 1.0) is None
    assert NULL_TRACER.begin("round", 0.0) is None
    assert NULL_TRACER.spans == []


def test_untraced_run_records_nothing(problem):
    loss_fn, eval_fn, p0, data = problem
    before = len(NULL_TRACER.spans)
    simulate.run(loss_fn, p0, data, _cfg(), eval_fn, eval_every=8)
    assert len(NULL_TRACER.spans) == before == 0


def test_tracer_nesting_and_views():
    tr = Tracer(run_id="t")
    rid = tr.begin("round", 0.0, clock=VIRTUAL, attrs={"k": 2})
    tr.add("local_steps", 0.0, 1.0, clock=VIRTUAL, track="client/0")
    tr.instant("broadcast", 2.0, clock=VIRTUAL)
    tr.end(rid, 2.0)
    with tr.span("stage", attrs={"s": 1}) as sp:
        sp.set(rounds=1)
    round_span = tr.find("round")[0]
    kids = list(tr.children(round_span))
    assert [s.name for s in kids] == ["local_steps", "broadcast"]
    assert all(s.parent == round_span.id for s in kids)
    assert round_span.parent == -1
    assert tr.find("broadcast")[0].duration == 0.0
    stage = tr.find("stage", clock=WALL)[0]
    assert stage.attrs == {"s": 1, "rounds": 1}
    # wall timestamps are excluded from the structural key, virtual kept
    assert stage.key()[6:8] == (None, None)
    assert round_span.key()[6:8] == (0.0, 2.0)


# ---------------------------------------------------------------------------
# Determinism: same seed ⇒ identical span tree
# ---------------------------------------------------------------------------

def _traced_run(problem, cfg):
    loss_fn, eval_fn, p0, data = problem
    tr = Tracer()
    runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8, tracer=tr)
    return tr


@pytest.mark.parametrize("kw", [
    dict(),                                                    # homogeneous
    dict(straggler_frac=0.25, straggler_slowdown=2.0,
         dropout_rate=0.25, upload_schedule="streaming"),      # event-rich
    dict(async_mode=True, straggler_frac=0.25,
         straggler_slowdown=2.0),                              # merge spans
], ids=["sync", "streaming-dropout", "async"])
def test_same_seed_same_span_tree(problem, kw):
    a = _traced_run(problem, _cfg(**kw))
    b = _traced_run(problem, _cfg(**kw))
    assert len(a.spans) > 0
    assert a.tree_keys() == b.tree_keys()


# ---------------------------------------------------------------------------
# The trace is the ledger: reduce_leaf ↔ leaf_costs reconciliation
# ---------------------------------------------------------------------------

def _shape_kw(shape):
    if shape == "streaming":
        return dict(upload_schedule="streaming")
    if shape == "hier":
        return dict(topology="hier", n_pods=2, inter_reducer="int8")
    return {}


@pytest.mark.parametrize("reducer", ["dense", "int8"])
@pytest.mark.parametrize("shape", ["star", "streaming", "hier"])
def test_modeled_leaf_spans_reconcile_with_hops(problem, reducer, shape):
    tr = _traced_run(problem, _cfg(reducer=reducer, **_shape_kw(shape)))
    hops = tr.find("reduce", clock=MODELED)
    leaves = tr.find("reduce_leaf", clock=MODELED)
    assert hops and leaves
    by_parent = {}
    for lf in leaves:
        by_parent.setdefault(lf.parent, []).append(lf)
    reconciled = 0
    for hop in hops:
        kids = by_parent.get(hop.id, [])
        if not kids:
            continue
        # bytes bit-exactly, seconds to float-sum precision — the same
        # invariant tests/test_streaming.py pins on the raw ledger
        assert sum(int(k.attrs["bytes"]) for k in kids) \
            == int(hop.attrs["bytes"])
        assert math.fsum(k.attrs["time_s"] for k in kids) \
            == pytest.approx(hop.attrs["time_s"], rel=1e-9, abs=1e-15)
        # leaf spans tile the hop interval back-to-back (serial α–β line)
        assert kids[0].t0 == pytest.approx(hop.t0, abs=1e-12)
        for a, b in zip(kids, kids[1:]):
            assert a.t1 == pytest.approx(b.t0, abs=1e-12)
        reconciled += 1
    assert reconciled > 0


def test_virtual_leaf_spans_match_leaf_ledger(problem):
    loss_fn, eval_fn, p0, data = problem
    cfg = _cfg(upload_schedule="streaming", straggler_frac=0.25,
               straggler_slowdown=2.0)
    tr = Tracer()
    res = runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8,
                      tracer=tr)
    assert res.leaf_ledger
    span_bytes = sum(int(s.attrs["bytes"])
                     for s in tr.find("reduce_leaf", clock=VIRTUAL))
    assert span_bytes == sum(int(l["bytes"]) for l in res.leaf_ledger)
    assert span_bytes == res.comm_bytes


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("comm.bytes", unit="B")
    c.inc(10, reducer="dense")
    c.inc(5, reducer="dense")
    c.inc(3, reducer="int8")
    assert c.value(reducer="dense") == 15
    assert c.value(reducer="int8") == 3
    assert c.value(reducer="topk") == 0
    g = reg.gauge("train.stage_objective")
    g.set(0.5, stage=1)
    g.set(0.25, stage=1)
    assert g.value(stage=1) == 0.25
    assert g.value(stage=2) is None
    h = reg.histogram("runtime.merge_staleness")
    for v in (0.0, 1.0, 3.0):
        h.observe(v, reducer="staleness")
    s = h.summary(reducer="staleness")
    assert s["count"] == 3 and s["sum"] == 4.0
    assert s["min"] == 0.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(4.0 / 3.0)
    assert h.summary(reducer="other") is None


def test_registry_idempotent_and_kind_checked():
    reg = obs_metrics.MetricsRegistry()
    a = reg.counter("x", unit="B")
    assert reg.counter("x") is a
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    assert "x" in reg and reg["x"] is a


def test_snapshot_is_serializable_and_sorted():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("b.count").inc(2, mode="sync")
    reg.gauge("a.obj", unit="loss").set(0.5)
    reg.histogram("c.h").observe(1.0)
    snap = reg.snapshot()
    assert list(snap) == ["a.obj", "b.count", "c.h"]
    assert snap["b.count"] == {"kind": "counter", "unit": "", "help": "",
                               "values": {"mode=sync": 2.0}}
    assert snap["c.h"]["values"][""]["mean"] == 1.0
    json.dumps(snap)                      # plain data, round-trippable


def test_engine_reports_metrics_into_registry(problem):
    loss_fn, eval_fn, p0, data = problem
    runtime.run(loss_fn, p0, data, _cfg(reducer="int8"), eval_fn,
                eval_every=8)
    reg = obs_metrics.registry()
    for name in ("engine.rounds", "engine.iters", "engine.stages",
                 "comm.bytes", "comm.time_s", "train.stage_objective"):
        assert name in reg, name
    assert reg["engine.stages"].value() == 2
    assert reg["comm.bytes"].value(hop="uplink", reducer="int8") > 0


def test_async_run_populates_staleness_and_message_metrics(problem):
    loss_fn, eval_fn, p0, data = problem
    runtime.run(loss_fn, p0, data,
                _cfg(async_mode=True, straggler_frac=0.25,
                     straggler_slowdown=2.0), eval_fn, eval_every=8)
    reg = obs_metrics.registry()
    stale = reg["runtime.merge_staleness"].summary(reducer="staleness")
    assert stale is not None and stale["count"] > 0
    assert reg["comm.messages"].value(reducer="staleness") == stale["count"]
    assert reg["comm.message_bytes"].value(reducer="staleness") > 0
    assert reg["comm.merge_weight"].summary(
        reducer="staleness")["count"] == stale["count"]


# ---------------------------------------------------------------------------
# Export: Chrome trace / Perfetto, JSONL
# ---------------------------------------------------------------------------

def _toy_tracer():
    tr = Tracer(run_id="toy")
    rid = tr.begin("round", 0.0, clock=VIRTUAL, track="server",
                   attrs={"k": 2})
    tr.add("local_steps", 0.0, 2e-3, cat="compute", clock=VIRTUAL,
           track="client/0", attrs={"steps": 2})
    tr.end(rid, 3e-3)
    tr.add("reduce", 0.0, 1e-3, clock=MODELED, track="hop/uplink",
           attrs={"bytes": 128})
    with tr.span("stage", attrs={"s": 1}):
        pass
    return tr


def test_chrome_trace_structure():
    tr = _toy_tracer()
    trace = to_chrome_trace(tr)
    assert trace["otherData"]["run_id"] == "toy"
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == len(tr.spans)
    # one process per clock domain present in the trace
    pnames = {e["pid"]: e["args"]["name"] for e in meta
              if e["name"] == "process_name"}
    assert set(pnames) == {1, 2, 3}       # virtual, modeled, wall
    tnames = {(e["pid"], e["tid"]): e["args"]["name"] for e in meta
              if e["name"] == "thread_name"}
    assert ("client/0" in tnames.values()
            and "hop/uplink" in tnames.values())
    # µs timestamps, attrs under args, phase colors attached
    steps = next(e for e in xs if e["name"] == "local_steps")
    assert steps["ts"] == 0.0 and steps["dur"] == pytest.approx(2e3)
    assert steps["args"]["steps"] == 2 and steps["args"]["clock"] == VIRTUAL
    assert steps["cname"] == "thread_state_running"
    # wall spans are rebased to t=0
    stage = next(e for e in xs if e["name"] == "stage")
    assert stage["ts"] == pytest.approx(0.0, abs=1.0)


def test_write_chrome_trace_and_jsonl_roundtrip(tmp_path):
    tr = _toy_tracer()
    p = write_chrome_trace(tr, str(tmp_path / "t.json"))
    loaded = json.load(open(p))
    assert any(e["ph"] == "X" for e in loaded["traceEvents"])
    pl = write_jsonl(tr, str(tmp_path / "t.jsonl"))
    rows = [json.loads(line) for line in open(pl)]
    assert len(rows) == len(tr.spans)
    assert rows[0]["name"] == "round" and rows[0]["parent"] == -1


# ---------------------------------------------------------------------------
# BENCH diffing and the CLI gate
# ---------------------------------------------------------------------------

def _bench(rows, name="toy", scale="smoke"):
    return {"bench": name, "schema": 1, "meta": {"scale": scale},
            "rows": rows}


def test_validate_bench_rejects_bad_schemas():
    with pytest.raises(BenchSchemaError, match="missing required key"):
        validate_bench({"schema": 1, "rows": []})
    with pytest.raises(BenchSchemaError, match="schema version"):
        validate_bench({"bench": "x", "schema": 2, "rows": []})
    with pytest.raises(BenchSchemaError, match="rows"):
        validate_bench({"bench": "x", "schema": 1, "rows": "nope"})
    with pytest.raises(BenchSchemaError, match="not an object"):
        validate_bench({"bench": "x", "schema": 1, "rows": [3]})
    rec = validate_bench({"bench": "x", "schema": 1, "rows": []})
    assert rec["meta"] == {}


def test_diff_benches_flags_regressions_not_improvements():
    base = _bench([{"algo": "stl_sc", "reducer": "dense",
                    "comm_time_s": 1.0, "rounds": 10}])
    cur = _bench([{"algo": "stl_sc", "reducer": "dense",
                   "comm_time_s": 1.10, "rounds": 8}])
    deltas = diff_benches(base, cur)
    by_key = {d.key: d for d in deltas}
    assert by_key["comm_time_s"].regressed(0.05)
    assert not by_key["comm_time_s"].regressed(0.15)
    assert by_key["rounds"].improved(0.05)
    assert not by_key["rounds"].regressed(0.05)
    assert by_key["comm_time_s"].ratio == pytest.approx(1.10)
    # unmatched rows and missing columns contribute nothing
    assert not diff_benches(base, _bench([{"algo": "other",
                                           "comm_time_s": 9.0}]))
    assert not diff_benches(base, _bench([{"algo": "stl_sc",
                                           "reducer": "dense"}]))


def _write_bench_dir(d, rows, scale="smoke"):
    d.mkdir(parents=True, exist_ok=True)
    (d / "BENCH_toy.json").write_text(json.dumps(_bench(rows, scale=scale)))


def test_diff_dirs_scale_mismatch_skips(tmp_path):
    row = [{"algo": "a", "comm_bytes": 100}]
    _write_bench_dir(tmp_path / "base", row, scale="full")
    _write_bench_dir(tmp_path / "cur", row, scale="smoke")
    dd = diff_dirs(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert not dd.compared and not dd.deltas
    assert any("scale mismatch" in s for s in dd.skipped)


def test_diff_dirs_reports_baseline_only(tmp_path):
    _write_bench_dir(tmp_path / "base", [{"algo": "a", "rounds": 1}])
    (tmp_path / "cur").mkdir()
    dd = diff_dirs(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert any("baseline only" in s for s in dd.skipped)


def _bench_diff_cli(*argv):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_diff.py"),
         *argv], capture_output=True, text=True)


def test_bench_diff_cli_exit_codes(tmp_path):
    base, cur = tmp_path / "base", tmp_path / "cur"
    row = [{"algo": "stl_sc", "reducer": "dense", "comm_time_s": 1.0,
            "comm_bytes": 1000}]
    _write_bench_dir(base, row)
    _write_bench_dir(cur, row)
    ok = _bench_diff_cli(str(base), str(cur))
    assert ok.returncode == 0, ok.stderr
    assert "0 regression(s)" in ok.stdout
    # inject a 10% modeled-seconds regression: must fail the 5% gate
    _write_bench_dir(cur, [dict(row[0], comm_time_s=1.10)])
    bad = _bench_diff_cli(str(base), str(cur))
    assert bad.returncode == 1
    assert "REGRESSED" in bad.stdout and "comm_time_s" in bad.stdout
    # ...and pass a looser one
    assert _bench_diff_cli(str(base), str(cur), "--tol", "0.2") \
        .returncode == 0
    # schema violations are usage errors, not regressions
    (base / "BENCH_toy.json").write_text('{"rows": []}')
    err = _bench_diff_cli(str(base), str(cur))
    assert err.returncode == 2 and "missing required key" in err.stderr
    # missing baseline dir
    assert _bench_diff_cli(str(tmp_path / "nope"), str(cur)) \
        .returncode == 2


# ---------------------------------------------------------------------------
# Structured logging and sync-step tags
# ---------------------------------------------------------------------------

def test_structured_logger_jsonl_and_levels():
    out = io.StringIO()
    log = StructuredLogger("t", stream=out, level="info", run_id="r1")
    log.debug("hidden", x=1)
    log.info("stage_done", stage=2, loss=0.5)
    rec = json.loads(out.getvalue())
    assert rec["event"] == "stage_done" and rec["stage"] == 2
    assert rec["level"] == "info" and rec["logger"] == "t"
    assert rec["run_id"] == "r1" and "mono_s" in rec
    assert "virtual_time_s" not in rec


def test_structured_logger_printf_compat_and_clock():
    out = io.StringIO()
    log = StructuredLogger("t", stream=out, level="info")
    class _Clk:
        now = 1.25
    log.bind_clock(_Clk())
    log.info("arch=%s clients=%d", "toy", 4)
    rec = json.loads(out.getvalue())
    assert rec["event"] == "log" and rec["msg"] == "arch=toy clients=4"
    assert rec["virtual_time_s"] == 1.25
    out.truncate(0), out.seek(0)
    log.quiet().error("anything")
    assert out.getvalue() == ""


def test_sync_step_tags_survive_jit():
    step = build_sync_step("int8", streaming=True)
    tags = sync_step_tags(jax.jit(step))
    # tags carry the built Reducer objects (the driver re-prices with the
    # exact instance the round transmits), not just their names
    assert tags["reducer"].name == "int8" and tags["streaming"]
    assert not tags["hierarchical"]
    hier = build_sync_step("dense", hierarchical=True, n_pods=2,
                           inter_reducer="int8")
    tags = sync_step_tags(hier)
    assert tags["hierarchical"] and tags["n_pods"] == 2
    assert tags["inter_reducer"].name == "int8"
