"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.comm import NetworkModel, get_reducer
from repro.core import schedules as S
from repro.data.partition import partition_iid, partition_paper
from repro.engine import get_topology
from repro.models.attention import _cache_positions
from repro.runtime import BlockingSchedule, ClientProcess, StreamingSchedule
from repro.utils.tree import (
    tree_broadcast_leading,
    tree_mean_leading,
)

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


@given(st.floats(1e-4, 0.5), st.integers(1, 1000), st.floats(0.5, 64.0),
       st.integers(1, 12), st.booleans())
def test_schedule_invariants(eta1, T1, k1, n_stages, iid):
    for algo in ("stl_sc", "stl_nc1", "stl_nc2"):
        stages = S.make_stages(algo, eta1, T1, k1, n_stages, iid)
        assert len(stages) == n_stages
        for a, b in zip(stages, stages[1:]):
            assert b.eta < a.eta or a.eta == b.eta  # non-increasing LR
            assert b.k_raw >= a.k_raw               # non-decreasing period
            assert b.T >= a.T
        assert all(s.k >= 1 for s in stages)
        # η_s·T_s is constant for geometric schedules (Theorem 2 invariant)
        if algo in ("stl_sc", "stl_nc1"):
            prods = [s.eta * s.T for s in stages]
            assert all(abs(p - prods[0]) < 1e-6 * max(1.0, prods[0]) for p in prods)


@given(st.floats(1e-4, 0.2), st.floats(0.5, 10.0), st.integers(1, 256),
       st.floats(0.1, 5.0), st.floats(0.0, 5.0))
def test_theory_k1_positive_and_monotone_in_N(eta, L, N, sigma, zeta):
    k_iid = S.theory_k1(eta, L, N, sigma, zeta, iid=True)
    k_non = S.theory_k1(eta, L, N, sigma, zeta, iid=False)
    assert k_iid > 0 and k_non > 0
    if N > 1:
        assert S.theory_k1(eta, L, N, sigma, zeta, True) <= \
            S.theory_k1(eta, L, max(1, N // 2), sigma, zeta, True) + 1e-12


@given(st.integers(2, 64), st.integers(0, 100), st.integers(0, 3))
def test_cache_positions_ring_invariants(C, pos, extra):
    """After writing token `pos` into slot pos%C, the slot map must (a) place
    position `pos` at slot pos%C, (b) contain exactly the last min(pos+1, C)
    positions, (c) mark never-written slots -1."""
    got = np.asarray(_cache_positions(C, jnp.asarray(pos)))
    assert got[pos % C] == pos
    valid = got[got >= 0]
    expect = np.arange(max(0, pos - C + 1), pos + 1)
    assert sorted(valid.tolist()) == expect.tolist()
    assert (got < 0).sum() == max(0, C - (pos + 1))


@given(st.integers(8, 200), st.integers(2, 8),
       st.integers(0, 100).map(lambda s: s % 101))
def test_partition_paper_invariants(n_per_client, n_clients, iid_pct):
    n = n_per_client * n_clients
    rng = np.random.RandomState(0)
    x = rng.randn(n, 3).astype(np.float32)
    y = rng.randint(0, 5, n)
    out = partition_paper(x, y, n_clients, iid_pct, seed=1)
    assert out["x"].shape[0] == n_clients
    # equal shares
    share = out["x"].shape[1]
    assert share * n_clients <= n
    # no example reused across clients
    flat = out["x"].reshape(-1, 3)
    as_tuples = {tuple(row) for row in np.round(flat, 6).tolist()}
    assert len(as_tuples) == flat.shape[0]


@given(st.integers(1, 6), st.integers(1, 5))
def test_broadcast_then_mean_roundtrip(n, dim):
    tree = {"w": jnp.arange(dim, dtype=jnp.float32), "b": jnp.ones((dim, 2))}
    stacked = tree_broadcast_leading(tree, n)
    back = tree_mean_leading(stacked)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
def test_comm_rounds_additive(T1, k1, n_stages):
    stages = S.make_stages("local", 0.1, T1 * 10, float(k1), n_stages, True)
    r = S.comm_rounds(stages)
    assert r == sum(math.ceil(s.T / s.k) for s in stages)


# ---------------------------------------------------------------------------
# Comm ledger partition laws: the per-(leaf, hop) view is an exact
# partition of the monolithic round — arbitrary leaf trees, reducers,
# topologies, with and without downlink billing
# ---------------------------------------------------------------------------

_leaf_sizes = st.lists(st.integers(1, 300), min_size=1, max_size=6)


def _template(sizes):
    return {f"l{i}": jnp.zeros((s,), jnp.float32)
            for i, s in enumerate(sizes)}


@given(_leaf_sizes, st.sampled_from(["dense", "int8", "int4", "topk"]))
def test_leaf_message_bytes_partition_message_bytes(sizes, spec):
    red = get_reducer(spec)
    tmpl = _template(sizes)
    lb = red.leaf_message_bytes(tmpl)
    assert len(lb) == len(sizes)
    assert all(b > 0 for b in lb)
    assert sum(lb) == red.message_bytes(tmpl)


@given(_leaf_sizes, st.sampled_from(["dense", "int8", "topk"]),
       st.sampled_from(["star", "streaming", "hier", "streaming-hier"]),
       st.sampled_from([2, 4, 8]), st.booleans())
def test_leaf_costs_partition_round_totals(sizes, spec, topo_spec, n,
                                           downlink):
    """Summing the per-(leaf, hop) ledger rows reproduces the tree-level
    round price exactly — bytes bit-exactly, modeled seconds to float-sum
    precision — for every topology × reducer × downlink-billing cell."""
    net = NetworkModel(latency_s=1e-4, bandwidth_gbps=1.0,
                       count_downlink=downlink)
    topo = get_topology(topo_spec, reducer=spec, network=net, n_pods=2,
                        inter_reducer=spec)
    tmpl = _template(sizes)
    lc = topo.leaf_costs(tmpl, n)
    hops = {h.hop for h in topo.hop_costs(tmpl, n)}
    assert {l.hop for l in lc} == hops
    assert ("downlink" in hops) == downlink
    # per-hop: leaf rows partition the hop's bytes exactly
    for h in topo.hop_costs(tmpl, n):
        rows = [l for l in lc if l.hop == h.hop]
        assert len(rows) == len(sizes)
        assert sorted(l.leaf for l in rows) == list(range(len(sizes)))
        assert sum(l.bytes for l in rows) == h.bytes
        assert math.fsum(l.time_s for l in rows) \
            == pytest.approx(h.time_s, rel=1e-12)
    # whole round: uplink + downlink rows sum to the monolithic price
    assert sum(l.bytes for l in lc) == topo.round_bytes(tmpl, n)
    assert math.fsum(l.time_s for l in lc) \
        == pytest.approx(topo.round_time(tmpl, n), rel=1e-12)


# ---------------------------------------------------------------------------
# Schedule tiling laws: per-leaf serialization windows are disjoint, sum
# to Σ bytes/β, and end at the schedule's finish — uplink and downlink
# ---------------------------------------------------------------------------

def _client_for(alpha, gbps, step_s=1e-3, downlink=True):
    return ClientProcess(cid=0, rate=1.0, step_time_s=step_s,
                         network=NetworkModel(latency_s=alpha,
                                              bandwidth_gbps=gbps,
                                              count_downlink=downlink))


def _assert_tiling(events, kind, leaf_bytes, Bps, finish, not_before):
    """Each per-leaf event closes a [fin − bytes/β, fin] serialization
    window; windows must be disjoint on the one serial link, start no
    earlier than the stream open, and the last must end at the finish."""
    wins = [(t - leaf_bytes[info[0]] / Bps, t)
            for t, k, info in events if k == kind]
    assert len(wins) == len(leaf_bytes)
    for (s0, e0), (s1, e1) in zip(wins, wins[1:]):
        assert s1 >= e0 - 1e-9 * max(1.0, abs(e0))  # no overlap
    assert wins[0][0] >= not_before - 1e-12
    assert wins[-1][1] == finish
    busy = math.fsum(e - s for s, e in wins)
    assert busy == pytest.approx(sum(leaf_bytes) / Bps, rel=1e-9)


@given(st.lists(st.integers(1, 10 ** 6), min_size=1, max_size=8),
       st.integers(1, 8), st.floats(1e-6, 1e-2), st.floats(0.05, 10.0),
       st.floats(0.0, 5.0))
def test_streaming_uplink_windows_tile_the_round(leaf_bytes, k, alpha, gbps,
                                                 start):
    c = _client_for(alpha, gbps)
    fracs = [b / sum(leaf_bytes) for b in leaf_bytes]
    evs, fin = StreamingSchedule().round_events(c, start, k, leaf_bytes,
                                                fracs)
    _assert_tiling(evs, "leaf_arrival", leaf_bytes, c.network.bandwidth_Bps,
                   fin, start + alpha)
    # streaming never loses to the blocking monolith on the same round
    _, fin_blk = BlockingSchedule().round_events(c, start, k, leaf_bytes,
                                                 fracs)
    assert fin <= fin_blk + 1e-9 * max(1.0, fin_blk)


@given(st.lists(st.integers(1, 10 ** 6), min_size=1, max_size=8),
       st.data(), st.floats(1e-6, 1e-2), st.floats(0.05, 10.0))
def test_streaming_downlink_windows_tile_the_broadcast(leaf_bytes, data,
                                                       alpha, gbps):
    leaf_done = [data.draw(st.floats(0.0, 2.0)) for _ in leaf_bytes]
    c = _client_for(alpha, gbps)
    evs, ready = StreamingSchedule().broadcast_events(c, leaf_done,
                                                      leaf_bytes)
    _assert_tiling(evs, "leaf_broadcast", leaf_bytes,
                   c.network.bandwidth_Bps, ready,
                   min(leaf_done) + alpha)
    # every leaf ships only after the server finished reducing it (the
    # stream opens — and pays α — once, at the first broadcast)
    for i, (t, _, (leaf,)) in enumerate(evs):
        lat = alpha if i == 0 else 0.0
        assert t >= leaf_done[leaf] + lat \
            + leaf_bytes[leaf] / c.network.bandwidth_Bps - 1e-12
    # the streamed downlink never loses to the blocking monolith, which
    # itself never beats the merge instant
    _, ready_blk = BlockingSchedule().broadcast_events(c, leaf_done,
                                                       leaf_bytes)
    assert ready <= ready_blk + 1e-9 * max(1.0, ready_blk)
    assert ready >= max(leaf_done)
    # unbilled downlink: both schedules are free and instant
    c_free = _client_for(alpha, gbps, downlink=False)
    assert StreamingSchedule().broadcast_events(
        c_free, leaf_done, leaf_bytes) == ([], max(leaf_done))
    assert BlockingSchedule().broadcast_events(
        c_free, leaf_done, leaf_bytes) == ([], max(leaf_done))
