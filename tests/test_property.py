"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core import schedules as S
from repro.data.partition import partition_iid, partition_paper
from repro.models.attention import _cache_positions
from repro.utils.tree import (
    tree_broadcast_leading,
    tree_mean_leading,
)

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


@given(st.floats(1e-4, 0.5), st.integers(1, 1000), st.floats(0.5, 64.0),
       st.integers(1, 12), st.booleans())
def test_schedule_invariants(eta1, T1, k1, n_stages, iid):
    for algo in ("stl_sc", "stl_nc1", "stl_nc2"):
        stages = S.make_stages(algo, eta1, T1, k1, n_stages, iid)
        assert len(stages) == n_stages
        for a, b in zip(stages, stages[1:]):
            assert b.eta < a.eta or a.eta == b.eta  # non-increasing LR
            assert b.k_raw >= a.k_raw               # non-decreasing period
            assert b.T >= a.T
        assert all(s.k >= 1 for s in stages)
        # η_s·T_s is constant for geometric schedules (Theorem 2 invariant)
        if algo in ("stl_sc", "stl_nc1"):
            prods = [s.eta * s.T for s in stages]
            assert all(abs(p - prods[0]) < 1e-6 * max(1.0, prods[0]) for p in prods)


@given(st.floats(1e-4, 0.2), st.floats(0.5, 10.0), st.integers(1, 256),
       st.floats(0.1, 5.0), st.floats(0.0, 5.0))
def test_theory_k1_positive_and_monotone_in_N(eta, L, N, sigma, zeta):
    k_iid = S.theory_k1(eta, L, N, sigma, zeta, iid=True)
    k_non = S.theory_k1(eta, L, N, sigma, zeta, iid=False)
    assert k_iid > 0 and k_non > 0
    if N > 1:
        assert S.theory_k1(eta, L, N, sigma, zeta, True) <= \
            S.theory_k1(eta, L, max(1, N // 2), sigma, zeta, True) + 1e-12


@given(st.integers(2, 64), st.integers(0, 100), st.integers(0, 3))
def test_cache_positions_ring_invariants(C, pos, extra):
    """After writing token `pos` into slot pos%C, the slot map must (a) place
    position `pos` at slot pos%C, (b) contain exactly the last min(pos+1, C)
    positions, (c) mark never-written slots -1."""
    got = np.asarray(_cache_positions(C, jnp.asarray(pos)))
    assert got[pos % C] == pos
    valid = got[got >= 0]
    expect = np.arange(max(0, pos - C + 1), pos + 1)
    assert sorted(valid.tolist()) == expect.tolist()
    assert (got < 0).sum() == max(0, C - (pos + 1))


@given(st.integers(8, 200), st.integers(2, 8),
       st.integers(0, 100).map(lambda s: s % 101))
def test_partition_paper_invariants(n_per_client, n_clients, iid_pct):
    n = n_per_client * n_clients
    rng = np.random.RandomState(0)
    x = rng.randn(n, 3).astype(np.float32)
    y = rng.randint(0, 5, n)
    out = partition_paper(x, y, n_clients, iid_pct, seed=1)
    assert out["x"].shape[0] == n_clients
    # equal shares
    share = out["x"].shape[1]
    assert share * n_clients <= n
    # no example reused across clients
    flat = out["x"].reshape(-1, 3)
    as_tuples = {tuple(row) for row in np.round(flat, 6).tolist()}
    assert len(as_tuples) == flat.shape[0]


@given(st.integers(1, 6), st.integers(1, 5))
def test_broadcast_then_mean_roundtrip(n, dim):
    tree = {"w": jnp.arange(dim, dtype=jnp.float32), "b": jnp.ones((dim, 2))}
    stacked = tree_broadcast_leading(tree, n)
    back = tree_mean_leading(stacked)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
def test_comm_rounds_additive(T1, k1, n_stages):
    stages = S.make_stages("local", 0.1, T1 * 10, float(k1), n_stages, True)
    r = S.comm_rounds(stages)
    assert r == sum(math.ceil(s.T / s.k) for s in stages)
