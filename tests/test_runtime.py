"""repro.runtime: event clock, heterogeneous clients, async merging.

The decisive invariants:
  * with heterogeneity disabled, EventBackend's synchronous path is
    bit-exact with the vmapped simulator — pinned against the same PR 2
    golden stl_sc trace as tests/test_engine.py, and bitwise-equal to
    ``simulate.run`` for EveryStep/FixedPeriod;
  * the clock is pure accounting: stragglers stretch modeled wall-clock
    without touching the trajectory; barrier rounds are priced at the
    slowest active client;
  * dropout is deterministic: same seed ⇒ identical event trace and final
    params, including hierarchical topology + error feedback;
  * AsyncPeriod is work-conserving: under stragglers it beats the
    synchronous schedule on modeled wall-clock at ~unchanged objective,
    and its StalenessWeightedMean merge is EF-compatible at int8;
  * AdaptivePeriod's divergence trigger interpolates between EveryStep
    (threshold 0) and the k-cap (threshold ∞).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import NetworkModel, StalenessWeightedMean, get_reducer
from repro.configs.base import TrainConfig
from repro.core import simulate
from repro import runtime
from repro.data import make_binary_classification, partition_iid
from repro.engine import (
    AdaptivePeriod,
    Algorithm,
    AsyncPeriod,
    Engine,
    FixedPeriod,
    StagewiseGeometric,
    get_algorithm,
    make_async,
)
from repro.models import logreg
from repro.runtime import (
    Clock,
    EventBackend,
    EventQueue,
    Heterogeneity,
    sample_clients,
)

# (round, iteration, objective) trace of the pre-engine core/simulate.py
# (commit f5d4d18) — stl_sc + DenseMean, seed 0, same problem as
# tests/test_engine.py::_GOLDEN_STL_SC. The event runtime must land on it
# bit-for-bit when heterogeneity is disabled.
_GOLDEN_STL_SC = [
    (0, 0, 0.6931471824645996), (1, 2, 0.6789301633834839),
    (2, 4, 0.6675747632980347), (3, 6, 0.6584702134132385),
    (4, 8, 0.6506574749946594), (5, 10, 0.6422803997993469),
    (6, 12, 0.6323944926261902), (7, 14, 0.6238881945610046),
    (8, 16, 0.6179242134094238), (9, 20, 0.6117205619812012),
    (10, 24, 0.6056254506111145), (11, 28, 0.5996546149253845),
    (12, 32, 0.595111608505249), (13, 36, 0.5898059010505676),
    (14, 40, 0.5841207504272461), (15, 44, 0.5793169140815735),
    (16, 48, 0.5756109356880188), (17, 56, 0.5715053081512451),
    (18, 64, 0.5678795576095581), (19, 72, 0.564716100692749),
    (20, 80, 0.5618601441383362), (21, 88, 0.558756411075592),
    (22, 96, 0.5559707283973694), (23, 104, 0.5533583164215088),
    (24, 112, 0.5510061979293823), (25, 128, 0.5486454963684082),
    (26, 144, 0.5460535883903503), (27, 160, 0.5438601970672607),
    (28, 176, 0.541716456413269), (29, 192, 0.5395599603652954),
    (30, 208, 0.5375436544418335), (31, 224, 0.5357033014297485),
    (32, 240, 0.53408282995224),
]


@pytest.fixture(scope="module")
def golden_problem():
    x, y = make_binary_classification(n=512, d=16, seed=3)
    lam = 1e-2
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=0).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = lambda p: logreg.full_objective(p, xj, yj, lam)
    return loss_fn, eval_fn, logreg.init_params(None, 16), data


def _golden_cfg(**kw):
    base = dict(algo="stl_sc", eta1=0.5, T1=16, k1=2.0, n_stages=4,
                iid=True, batch_per_client=8, seed=0)
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# Clock / client sampling primitives
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_insertion():
    q = EventQueue()
    q.push(2.0, "b", 1)
    q.push(1.0, "a", 0)
    q.push(1.0, "c", 2)   # same time as "a": FIFO tie-break
    got = [(q.pop().kind) for _ in range(3)]
    assert got == ["a", "c", "b"]
    clock = Clock()
    assert clock.advance(1.5) == 1.5
    assert clock.advance(1.0) == 1.5  # time never flows backwards


def test_sample_clients_deterministic_and_stragglers():
    het = Heterogeneity(base_step_time_s=1e-3, straggler_frac=0.25,
                        straggler_slowdown=4.0, jitter=0.1, seed=7)
    a = sample_clients(8, het)
    b = sample_clients(8, het)
    assert a == b  # pure function of (n, profile)
    assert sum(c.straggler for c in a) == 2
    strag = [c for c in a if c.straggler]
    rest = [c for c in a if not c.straggler]
    assert min(c.step_time_s for c in strag) > max(c.step_time_s
                                                   for c in rest)
    # jitter actually varies the cohort
    assert len({c.rate for c in rest}) > 1
    # homogeneous profile: all identical, nominal rate
    hom = sample_clients(4, Heterogeneity())
    assert not Heterogeneity().enabled
    assert all(c.rate == 1.0 and c.step_time_s == 1e-3 for c in hom)


# ---------------------------------------------------------------------------
# Bit-exactness: EventBackend == vmapped simulator when homogeneous
# ---------------------------------------------------------------------------

def test_event_backend_stl_sc_bit_exact_with_golden_trace(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    res = runtime.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                      eval_every=1)
    got = [(h.round, h.iteration, float(h.value)) for h in res.history]
    assert got == [(r, i, v) for r, i, v in _GOLDEN_STL_SC]
    # and the clock priced 32 homogeneous barrier rounds
    assert res.rounds == 32
    assert res.wall_clock_s > 0.0


@pytest.mark.parametrize("algo,kw", [
    ("sync", dict(T1=24, k1=1.0, n_stages=2)),       # EveryStep
    ("local", dict(T1=24, k1=4.0, n_stages=2)),      # FixedPeriod
    ("stl_sc", dict(T1=12, k1=2.0, n_stages=3)),     # StagewiseGeometric
])
def test_event_backend_matches_simulator_bitwise(golden_problem, algo, kw):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = _golden_cfg(algo=algo, **kw)
    h_sim = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=2)
    res = runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=2)
    assert [(h.round, h.iteration, h.value) for h in h_sim] \
        == [(h.round, h.iteration, h.value) for h in res.history]


def test_stragglers_stretch_clock_not_trajectory(golden_problem):
    """Stragglers are pure clock: the barrier keeps numerics identical while
    every round is priced at the slowest client."""
    loss_fn, eval_fn, p0, data = golden_problem
    base = runtime.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                       eval_every=1)
    slow = runtime.run(
        loss_fn, p0, data,
        _golden_cfg(straggler_frac=0.25, straggler_slowdown=4.0),
        eval_fn, eval_every=1)
    assert [(h.round, h.value) for h in base.history] \
        == [(h.round, h.value) for h in slow.history]
    assert slow.wall_clock_s > 2.0 * base.wall_clock_s
    # per-round cost = k·(slowest step time) + slowest upload (+ α)
    het = Heterogeneity(straggler_frac=0.25, straggler_slowdown=4.0, seed=0)
    clients = sample_clients(4, het, NetworkModel())
    msg = get_reducer("dense").message_bytes(p0)
    k1_round = 2 * max(c.step_time_s for c in clients) \
        + max(c.upload_time(msg) for c in clients)
    assert slow.timeline[1][0] == pytest.approx(k1_round)


# ---------------------------------------------------------------------------
# Dropout determinism (sync masked path + hierarchical topology + EF)
# ---------------------------------------------------------------------------

def _dropout_cfg(**kw):
    return _golden_cfg(dropout_rate=0.25, straggler_frac=0.25,
                       straggler_slowdown=2.0, **kw)


def test_dropout_same_seed_identical_trace_and_params(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    runs = [runtime.run(loss_fn, p0, data, _dropout_cfg(), eval_fn,
                        eval_every=2) for _ in range(2)]
    assert runs[0].trace == runs[1].trace
    assert len(runs[0].trace) > 0
    for a, b in zip(jax.tree.leaves(runs[0].params),
                    jax.tree.leaves(runs[1].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [(h.round, h.value) for h in runs[0].history] \
        == [(h.round, h.value) for h in runs[1].history]
    # dropout actually bites: trajectory differs from full participation
    full = runtime.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                       eval_every=2)
    assert [h.value for h in full.history] \
        != [h.value for h in runs[0].history]
    assert any(e[1] == "dropout" for e in runs[0].trace)
    # a dropped client still answers the barrier with its zero-delta
    # message (matching the masked numerics): every round sees N arrivals
    kinds = [e[1] for e in runs[0].trace]
    assert kinds.count("arrival") == 4 * kinds.count("merge")
    assert kinds.count("compute_done") \
        == 4 * kinds.count("merge") - kinds.count("dropout")


def test_dropout_hierarchical_ef_deterministic_and_converges(golden_problem):
    """Dropped clients contribute a zero delta, so the hierarchical
    dense-ICI + int8-EF-WAN topology composes with partial participation:
    same seed reproduces the run exactly, and the objective still lands
    near the flat-dense run."""
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = _dropout_cfg(topology="hier", n_pods=2, inter_reducer="int8")
    runs = [runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=4)
            for _ in range(2)]
    assert runs[0].trace == runs[1].trace
    for a, b in zip(jax.tree.leaves(runs[0].params),
                    jax.tree.leaves(runs[1].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat = runtime.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                       eval_every=4)
    assert abs(runs[0].history[-1].value - flat.history[-1].value) < 2e-2
    # the inter-pod hop is priced on every replayed round
    assert runs[0].wall_clock_s > 0.0
    assert any(e[1] == "merge" for e in runs[0].trace)


def test_async_dropout_same_seed_identical(golden_problem):
    """momentum > 0 also exercises the drop path's optimizer-state restore
    (a discarded job must not leak momentum/schedule progress)."""
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = _dropout_cfg(async_mode=True, momentum=0.5)
    runs = [runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=4)
            for _ in range(2)]
    assert runs[0].trace == runs[1].trace
    assert any(e[1] == "drop" for e in runs[0].trace)
    for a, b in zip(jax.tree.leaves(runs[0].params),
                    jax.tree.leaves(runs[1].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# AsyncPeriod semantics
# ---------------------------------------------------------------------------

def test_async_suffix_and_make_async_registry():
    algo = get_algorithm("stl_sc+async")
    assert isinstance(algo.sync_policy, AsyncPeriod)
    assert isinstance(algo.sync_policy.base, StagewiseGeometric)
    assert algo.sync_policy.asynchronous
    assert make_async(algo) is algo  # idempotent
    # the schedule is the base policy's, untouched
    cfg = _golden_cfg()
    assert algo.stages(cfg) == get_algorithm("stl_sc").stages(cfg)
    # prox flag and recenter survive the wrap
    nc = get_algorithm("stl_nc1+async")
    assert nc.prox and nc.sync_policy.recenter


def test_async_rejected_by_vmap_simulator(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    with pytest.raises(ValueError, match="EventBackend"):
        simulate.run(loss_fn, p0, data, _golden_cfg(algo="stl_sc+async"),
                     eval_fn)


def test_async_and_adaptive_rejected_by_driver():
    """The pjit driver's (train_step, sync_step) contract is a barriered
    fixed-schedule round — it must refuse rather than silently run the
    wrong semantics under the right algorithm name, and the refusal must
    name the offending policy and point at the backend that CAN run it."""
    from repro.core.stl_sgd import StagewiseDriver

    with pytest.raises(ValueError) as ei:
        StagewiseDriver(TrainConfig(algo="local+async"),
                        lambda s, b, e: (s, {}), lambda s: s)
    msg = str(ei.value)
    assert "AsyncPeriod" in msg           # names the policy
    assert "local+async" in msg           # names the algorithm
    assert "EventBackend" in msg          # points at the right backend
    assert "runtime" in msg

    with pytest.raises(ValueError) as ei:
        StagewiseDriver(TrainConfig(algo="adaptive"),
                        lambda s, b, e: (s, {}), lambda s: s)
    msg = str(ei.value)
    assert "AdaptivePeriod" in msg
    assert "adaptive" in msg
    assert "simulate.run" in msg or "EventBackend" in msg


def test_async_run_rejects_explicit_topology(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    from repro.engine import Hierarchical

    with pytest.raises(ValueError, match="topology"):
        runtime.run(loss_fn, p0, data, _golden_cfg(async_mode=True),
                    eval_fn, topology=Hierarchical(n_pods=2))
    with pytest.raises(ValueError, match="star"):
        runtime.run(loss_fn, p0, data,
                    _golden_cfg(async_mode=True, topology="hier"), eval_fn)


def test_async_homogeneous_tracks_sync_objective(golden_problem):
    """Same work budget, merge-on-arrival: the homogeneous async run lands
    within 1% of the synchronous objective (staleness ≈ 0 ⇒ full-weight
    merges) and consumes the same modeled wall-clock."""
    loss_fn, eval_fn, p0, data = golden_problem
    sync = runtime.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                       eval_every=8)
    asyn = runtime.run(loss_fn, p0, data, _golden_cfg(async_mode=True),
                       eval_fn, eval_every=8)
    assert asyn.iters == 4 * sync.iters  # per-client steps vs vmapped slots
    drift = abs(asyn.history[-1].value - sync.history[-1].value) \
        / sync.history[-1].value
    assert drift < 0.01, drift
    assert asyn.wall_clock_s == pytest.approx(sync.wall_clock_s)


def test_async_beats_sync_wall_clock_under_stragglers(golden_problem):
    """The table5 acceptance bar in miniature: ≥2× straggler slowdown ⇒
    async wins modeled wall-clock at <1% objective drift."""
    loss_fn, eval_fn, p0, data = golden_problem
    kw = dict(algo="local", T1=64, k1=8.0, n_stages=3,
              straggler_frac=0.25, straggler_slowdown=2.0)
    sync = runtime.run(loss_fn, p0, data, _golden_cfg(**kw), eval_fn,
                       eval_every=8)
    asyn = runtime.run(loss_fn, p0, data,
                       _golden_cfg(async_mode=True, **kw), eval_fn,
                       eval_every=8)
    assert asyn.wall_clock_s < sync.wall_clock_s
    drift = abs(asyn.history[-1].value - sync.history[-1].value) \
        / sync.history[-1].value
    assert drift < 0.01, drift
    # work-conserving: fast clients take more jobs than the straggler
    per_client = {}
    for t, kind, cid in asyn.trace:
        if kind == "compute_done":
            per_client[cid] = per_client.get(cid, 0) + 1
    strag = {c.cid for c in sample_clients(
        4, Heterogeneity(straggler_frac=0.25, straggler_slowdown=2.0,
                         seed=0)) if c.straggler}
    assert strag
    assert max(per_client[c] for c in strag) \
        < max(v for c, v in per_client.items() if c not in strag)


def test_async_int8_messages_track_dense(golden_problem):
    """StalenessWeightedMean reuses the int8 quantize path with per-client
    EF residuals: compressed async lands near dense async, and the engine
    ledger prices the ~4× smaller uploads."""
    loss_fn, eval_fn, p0, data = golden_problem
    dense = runtime.run(loss_fn, p0, data, _golden_cfg(async_mode=True),
                        eval_fn, eval_every=8)
    comp = runtime.run(loss_fn, p0, data,
                       _golden_cfg(async_mode=True, reducer="int8"),
                       eval_fn, eval_every=8)
    assert abs(comp.history[-1].value - dense.history[-1].value) \
        / dense.history[-1].value < 0.01
    assert dense.comm_bytes > 3 * comp.comm_bytes
    assert comp.rounds == dense.rounds


def test_staleness_weighted_mean_unit():
    red = StalenessWeightedMean(decay=0.5)
    assert red.weight(0) == 1.0
    assert red.weight(3) == pytest.approx(0.5)
    assert red.weight(-1) == 1.0  # clamped
    tmpl = {"w": jnp.arange(4.0), "b": jnp.zeros((2,))}
    res = red.client_residual(tmpl)
    assert all(float(jnp.sum(jnp.abs(l))) == 0.0
               for l in jax.tree.leaves(res))
    delta = {"w": jnp.ones((4,)), "b": jnp.full((2,), 2.0)}
    payload, res2 = red.encode(delta, res, jax.random.key(0))
    for a, b in zip(jax.tree.leaves(payload), jax.tree.leaves(delta)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    merged = red.merge(tmpl, payload, staleness=3.0, n_clients=2)
    np.testing.assert_allclose(np.asarray(merged["w"]),
                               np.asarray(tmpl["w"] + 0.25))
    # int8 messages: EF residual carries the lattice error
    red8 = StalenessWeightedMean(decay=0.5, compress="int", bits=8)
    assert red8.name == "staleness-int8"
    p8, r8 = red8.encode(delta, red8.client_residual(tmpl),
                         jax.random.key(1))
    for d, p, r in zip(jax.tree.leaves(delta), jax.tree.leaves(p8),
                       jax.tree.leaves(r8)):
        np.testing.assert_allclose(np.asarray(p + r), np.asarray(d),
                                   rtol=1e-5, atol=1e-6)
    assert red8.message_bytes(tmpl) < red.message_bytes(tmpl)
    assert get_reducer("staleness-int4").bits == 4
    with pytest.raises(ValueError):
        runtime.staleness_reducer_for(TrainConfig(reducer="topk",
                                                  async_mode=True))


# ---------------------------------------------------------------------------
# AdaptivePeriod (divergence-triggered rounds)
# ---------------------------------------------------------------------------

def test_adaptive_registry_and_limits(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    algo = get_algorithm("adaptive")
    assert isinstance(algo.sync_policy, AdaptivePeriod)
    assert algo.sync_policy.adaptive
    cfg = _golden_cfg(algo="adaptive", T1=8, n_stages=2, k1=4.0)

    def rounds_at(threshold):
        a = Algorithm("adaptive_t", AdaptivePeriod(
            base=FixedPeriod(), threshold=threshold))
        eng = Engine(a, cfg)
        be = simulate.VmapSimulatorBackend(loss_fn, p0, data, eval_fn,
                                           eval_every=1)
        hist = eng.run(be)
        return hist[-1].round, hist[-1].iteration

    r_zero, iters = rounds_at(0.0)
    assert r_zero == iters == 16          # threshold 0 ⇒ EveryStep
    r_inf, _ = rounds_at(float("inf"))
    assert r_inf == 4                     # cap-triggered ⇒ ceil(T/k) rounds
    r_mid, _ = rounds_at(3e-4)
    assert r_inf <= r_mid <= r_zero


def test_adaptive_converges_between_sync_and_local(golden_problem):
    loss_fn, eval_fn, p0, data = golden_problem
    cfg = _golden_cfg(algo="adaptive")
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8)
    ref = simulate.run(loss_fn, p0, data, _golden_cfg(), eval_fn,
                       eval_every=8)
    # fewer rounds than EveryStep, same iteration budget, ~same objective
    assert hist[-1].iteration == ref[-1].iteration
    assert hist[-1].round < hist[-1].iteration
    assert abs(hist[-1].value - ref[-1].value) / ref[-1].value < 0.01
