"""Schedule math vs the paper's Algorithms 2/3 and Table 3."""
import math

import pytest

from repro.core import schedules as S


def test_stl_sc_geometric_progression():
    st = S.make_stages("stl_sc", eta1=0.4, T1=100, k1=4, n_stages=5, iid=True)
    for i, stage in enumerate(st):
        assert stage.eta == pytest.approx(0.4 / 2 ** i)
        assert stage.T == 100 * 2 ** i
        assert stage.k_raw == pytest.approx(4 * 2 ** i)


def test_stl_sc_noniid_sqrt2_growth():
    st = S.make_stages("stl_sc", 0.4, 100, 4, 5, iid=False)
    for a, b in zip(st, st[1:]):
        assert b.k_raw / a.k_raw == pytest.approx(math.sqrt(2.0))


def test_eta_T_product_invariant_sc():
    # Algorithm 2 keeps η_s·T_s constant (= 6/μ in Theorem 2)
    st = S.make_stages("stl_sc", 0.32, 64, 2, 7, iid=True)
    prods = [s.eta * s.T for s in st]
    assert all(p == pytest.approx(prods[0]) for p in prods)


def test_stl_nc2_linear_schedule():
    st = S.make_stages("stl_nc2", 0.3, 50, 3, 6, iid=True)
    for i, stage in enumerate(st, start=1):
        assert stage.eta == pytest.approx(0.3 / i)
        assert stage.T == 50 * i
        assert stage.k_raw == pytest.approx(3 * i)
    st_n = S.make_stages("stl_nc2", 0.3, 50, 3, 6, iid=False)
    for i, stage in enumerate(st_n, start=1):
        assert stage.k_raw == pytest.approx(3 * math.sqrt(i))


def test_k_floor_at_one():
    st = S.make_stages("stl_sc", 0.4, 10, 0.3, 3, iid=True)
    assert all(s.k >= 1 for s in st)


def test_theory_k1_formulas():
    # IID: min(1/(6ηLN), 1/(9ηL)); Non-IID variance-scaled
    eta, L, N = 0.01, 2.0, 16
    k_iid = S.theory_k1(eta, L, N, iid=True)
    assert k_iid == pytest.approx(min(1 / (6 * eta * L * N), 1 / (9 * eta * L)))
    k_non = S.theory_k1(eta, L, N, sigma=1.0, zeta=0.5, iid=False)
    assert k_non == pytest.approx(
        min(1 / math.sqrt(6 * eta * L * N * 3.0), 1 / (9 * eta * L)))
    # Non-IID admissible period never exceeds IID's O(1/√(ηN)) scaling
    assert k_non <= S.theory_k1(eta, L, N, sigma=1.0, zeta=0.0, iid=False) + 1e-12


def test_k1_inversely_proportional_to_eta():
    # the paper's key insight: k ∝ 1/η (IID)
    L, N = 2.0, 8
    k_a = S.theory_k1(0.01, L, N, iid=True)
    k_b = S.theory_k1(0.005, L, N, iid=True)
    assert k_b == pytest.approx(2 * k_a)


def test_comm_complexity_orders_match_table3():
    """Σ T_s/k_s growth matches the claimed orders as T grows."""
    eta1, T1, k1 = 0.4, 64, 4

    def rounds(algo, n_stages, iid):
        return S.comm_rounds(S.make_stages(algo, eta1, T1, k1, n_stages, iid))

    # IID stl_sc: rounds = S·T1/k1 → O(log T): linear in stage count
    r = [rounds("stl_sc", s, True) for s in (4, 8, 12)]
    assert abs((r[1] - r[0]) - (r[2] - r[1])) <= 2  # arithmetic in S

    # Non-IID stl_sc: rounds ≈ (T1/k1)·(√2)^S geometric → ratio ~√2 per stage
    r8, r10 = rounds("stl_sc", 8, False), rounds("stl_sc", 10, False)
    assert r10 / r8 == pytest.approx(2.0, rel=0.15)  # (√2)² per two stages

    # sync: rounds == T
    st = S.make_stages("sync", eta1, T1, 1, 5, True)
    assert S.comm_rounds(st) == S.total_iters(st)


def test_min_stages_sc():
    s = S.min_stages_sc(N=32, f_gap0=1.0, eta1=0.1, sigma=1.0)
    assert s >= 2
    assert s == math.ceil(math.log2(32 * 1.0 / 0.1)) + 2
