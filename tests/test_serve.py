"""repro.serve: continuous batching is invisible to each request.

The load-bearing properties:

  * batching invariance — a request's token stream is bit-exact with the
    per-request ``greedy_decode`` reference, for every arrival order and
    slot assignment (the decode step is vmapped over independent per-slot
    caches, so lanes cannot interact);
  * determinism — same traffic seed ⇒ identical request ledger and span
    tree (everything scheduled on the virtual clock, nothing measured);
  * the latency ledger's percentiles are exact (numpy-equal);
  * train --ckpt-out → ServeEngine.from_checkpoint round-trips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.serving import greedy_decode
from repro.models import transformer as TF
from repro.obs import Tracer
from repro.obs.metrics import MetricsRegistry
from repro.serve import (
    Request,
    Scheduler,
    SchedulerConfig,
    ServeEngine,
    SlotPool,
    TrafficConfig,
    generate_requests,
    offered_load,
)

SCHED = SchedulerConfig(n_slots=3, max_seq_len=48, max_queue=32)


@pytest.fixture(scope="module")
def cfg():
    return get_arch("qwen3-14b", smoke=True)


@pytest.fixture(scope="module")
def params(cfg):
    return TF.init_params(jax.random.key(0), cfg)


@pytest.fixture(scope="module")
def engine(cfg, params):
    return ServeEngine(cfg, params, scheduler=SCHED)


@pytest.fixture(scope="module")
def traffic(cfg):
    tcfg = TrafficConfig(process="poisson", rate_rps=2e5, n_requests=9,
                         mean_prompt_len=6, max_prompt_len=12,
                         mean_out_len=5, max_out_len=10, seed=7)
    return generate_requests(tcfg, cfg.vocab_size)


# -- traffic ----------------------------------------------------------------

def test_traffic_deterministic_and_bounded(cfg):
    tcfg = TrafficConfig(process="bursty", rate_rps=50.0, n_requests=16,
                         seed=11)
    a, b = (generate_requests(tcfg, cfg.vocab_size) for _ in range(2))
    assert len(a) == 16
    for ra, rb in zip(a, b):
        assert ra.arrival_s == rb.arrival_s
        assert ra.n_out == rb.n_out
        assert np.array_equal(ra.prompt, rb.prompt)
    times = [r.arrival_s for r in a]
    assert times == sorted(times) and times[0] > 0.0
    for r in a:
        assert 1 <= r.prompt_len <= tcfg.max_prompt_len
        assert 1 <= r.n_out <= tcfg.max_out_len
        assert r.prompt.dtype == np.int32
        assert r.prompt.min() >= 0 and r.prompt.max() < cfg.vocab_size


def test_traffic_unknown_process_raises(cfg):
    with pytest.raises(ValueError, match="unknown arrival process"):
        generate_requests(TrafficConfig(process="uniform"), cfg.vocab_size)


def test_offered_load_fifo_tie_break(cfg):
    reqs = [Request(id=i, arrival_s=1.0, prompt=np.zeros(2, np.int32),
                    n_out=1) for i in range(4)]
    q = offered_load(reqs)
    assert [q.pop().client for _ in range(4)] == [0, 1, 2, 3]


# -- scheduler --------------------------------------------------------------

def test_slot_pool_lowest_index_first():
    pool = SlotPool(3)
    assert [pool.alloc() for _ in range(3)] == [0, 1, 2]
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(1)
    pool.free(0)
    assert pool.alloc() == 0          # lowest free index, not LIFO
    with pytest.raises(ValueError):
        pool.free(1)                  # double free
    with pytest.raises(ValueError):
        pool.free(9)                  # out of range


def _req(rid, plen, n_out, arrival=0.0):
    return Request(id=rid, arrival_s=arrival,
                   prompt=np.zeros(plen, np.int32), n_out=n_out)


def test_scheduler_rejects_and_admits_fcfs():
    cfg = SchedulerConfig(n_slots=2, max_seq_len=16, max_queue=2,
                          max_prefills_per_step=1)
    s = Scheduler(cfg)
    assert not s.offer(_req(0, 20, 4))            # footprint > max_seq_len
    assert s.rejected_too_long[0].id == 0
    assert s.offer(_req(1, 4, 4)) and s.offer(_req(2, 4, 4))
    assert not s.offer(_req(3, 4, 4))             # queue bound
    assert s.rejected_full[0].id == 3
    adm = s.admit()
    assert [a.request.id for a in adm] == [1]     # prefill cap: one per step
    assert adm[0].slot == 0
    adm2 = s.admit()
    assert [a.request.id for a in adm2] == [2] and adm2[0].slot == 1
    assert s.occupancy == 2 and s.queue_depth == 0
    released = s.release(0)
    assert released.id == 1 and s.pool.n_free == 1


def test_scheduler_token_budget_blocks_head_strict_fcfs():
    cfg = SchedulerConfig(n_slots=4, max_seq_len=16, token_budget=20)
    s = Scheduler(cfg)
    assert s.offer(_req(0, 10, 5))    # footprint 15
    assert s.offer(_req(1, 10, 5))    # 15 — doesn't fit alongside req 0
    assert s.offer(_req(2, 1, 1))     # 2 — would fit, must NOT overtake
    assert [a.request.id for a in s.admit()] == [0]
    assert s.admit() == []            # head blocked on budget, strict FCFS
    s.release(0)
    assert [a.request.id for a in s.admit()] == [1]


def test_scheduler_budget_guard_rejects_unservable():
    # footprint fits max_seq_len but can never fit a tiny custom budget:
    # must reject at offer() time, not wedge the queue head forever
    cfg = SchedulerConfig(n_slots=2, max_seq_len=16, token_budget=8)
    s = Scheduler(cfg)
    assert not s.offer(_req(0, 8, 4))
    assert s.rejected_too_long and s.idle


def test_scheduler_frontend_tokens_count(cfg):
    s = Scheduler(SchedulerConfig(n_slots=1, max_seq_len=16),
                  n_frontend_tokens=10)
    fe = np.zeros((10, 4), np.float32)
    r = Request(id=0, arrival_s=0.0, prompt=np.zeros(4, np.int32), n_out=4,
                frontend=fe)
    assert not s.offer(r)             # 4 + 4 + 10 = 18 > 16
    assert s.offer(dataclasses.replace(r, frontend=None))


# -- engine: batching invariance -------------------------------------------

def _reference_tokens(params, cfg, requests):
    out = {}
    for r in requests:
        ref = greedy_decode(params, cfg, jnp.asarray(r.prompt[None, :]),
                            r.n_out, SCHED.max_seq_len)
        out[r.id] = np.asarray(ref)[0].tolist()
    return out


def test_batched_decode_bit_exact_across_arrival_orders(
        cfg, params, engine, traffic):
    ref = _reference_tokens(params, cfg, traffic)
    # order A: as generated; order B: arrival times reversed across ids,
    # so admission order, slot assignment and batch composition all change
    rev = sorted(r.arrival_s for r in traffic)[::-1]
    reordered = sorted(
        (dataclasses.replace(r, arrival_s=t) for r, t in zip(traffic, rev)),
        key=lambda r: r.arrival_s)
    slots_seen = []
    for reqs in (traffic, reordered):
        report = engine.run(list(reqs), registry=MetricsRegistry())
        assert len(report.completed) == len(traffic)
        for rec in report.records:
            assert rec.tokens == ref[rec.id], \
                f"req {rec.id} diverged in slot {rec.slot}"
        slots_seen.append([r.slot for r in report.records])
    # the invariance was exercised: the two runs really batched differently
    assert slots_seen[0] != slots_seen[1]


def test_single_token_requests_retire_at_prefill(cfg, params, engine):
    reqs = [_req(i, 4, 1, arrival=i * 1e-6) for i in range(4)]
    for i, r in enumerate(reqs):
        reqs[i] = dataclasses.replace(
            r, prompt=np.full(4, i + 1, np.int32))
    report = engine.run(reqs, registry=MetricsRegistry())
    ref = _reference_tokens(params, cfg, reqs)
    for rec in report.records:
        assert rec.outcome == "completed" and len(rec.tokens) == 1
        assert rec.tokens == ref[rec.id]
        assert rec.finish_s == rec.first_token_s and rec.tpot_s == 0.0


def test_engine_frontend_arch_bit_exact():
    fcfg = get_arch("internvl2-2b", smoke=True)
    fparams = TF.init_params(jax.random.key(1), fcfg)
    rng = np.random.RandomState(5)
    max_len = 64
    sched = SchedulerConfig(n_slots=2, max_seq_len=max_len)
    eng = ServeEngine(fcfg, fparams, scheduler=sched)
    reqs = []
    for i in range(3):
        fe = rng.randn(fcfg.n_frontend_tokens,
                       fcfg.frontend_dim).astype(np.float32)
        reqs.append(Request(
            id=i, arrival_s=(i + 1) * 1e-6,
            prompt=rng.randint(0, fcfg.vocab_size, size=(6,)).astype(
                np.int32),
            n_out=4, frontend=fe))
    report = eng.run(reqs, registry=MetricsRegistry())
    for r, rec in zip(reqs, report.records):
        fe = jnp.asarray(r.frontend[None], jnp.bfloat16)
        ref = greedy_decode(fparams, fcfg, jnp.asarray(r.prompt[None, :]),
                            r.n_out, max_len, frontend=fe)
        assert rec.tokens == np.asarray(ref)[0].tolist()


# -- engine: determinism ----------------------------------------------------

def test_same_seed_same_ledger_and_span_tree(cfg, params, engine, traffic):
    runs = []
    for _ in range(2):
        tracer = Tracer()
        report = engine.run(list(traffic), tracer=tracer,
                            registry=MetricsRegistry())
        runs.append((report, tracer))
    ra, rb = runs[0][0], runs[1][0]
    assert ra.trace_keys() == rb.trace_keys()
    assert ra.makespan_s == rb.makespan_s and ra.n_steps == rb.n_steps
    # span trees identical including virtual-clock timestamps (wall spans
    # compare structurally — Span.key masks their timestamps)
    assert runs[0][1].tree_keys() == runs[1][1].tree_keys()


def test_ledger_span_taxonomy(cfg, params, engine, traffic):
    tracer = Tracer()
    report = engine.run(list(traffic), tracer=tracer,
                        registry=MetricsRegistry())
    reqs = tracer.find("request")
    assert len(reqs) == len(report.completed)
    for span in reqs:
        kids = [s.name for s in tracer.children(span)]
        assert kids == ["queue", "prefill", "decode"]
    steps = tracer.find("decode_step")
    assert len(steps) == report.n_steps
    assert all(s.track == "server" for s in steps)
    # queue + prefill + decode tile the request span exactly
    for span in reqs:
        kids = {s.name: s for s in tracer.children(span)}
        assert kids["queue"].t0 == span.t0
        assert kids["queue"].t1 == kids["prefill"].t0
        assert kids["prefill"].t1 == kids["decode"].t0
        assert kids["decode"].t1 == span.t1


def test_rejections_recorded(cfg, params):
    eng = ServeEngine(cfg, params, scheduler=SchedulerConfig(
        n_slots=1, max_seq_len=16, max_queue=1))
    reqs = [_req(0, 40, 8, arrival=1e-6),          # too long
            _req(1, 4, 4, arrival=2e-6),           # takes the one queue slot
            _req(2, 4, 4, arrival=2e-6),           # queue bound: rejected
            _req(3, 4, 4, arrival=2e-6)]           # queue bound: rejected
    for r in reqs[1:]:
        r.prompt[:] = r.id
    reg = MetricsRegistry()
    report = eng.run(reqs, registry=reg)
    outcomes = {r.id: r.outcome for r in report.records}
    assert outcomes[0] == "rejected_too_long"
    assert outcomes[1] == "completed"
    assert outcomes[2] == outcomes[3] == "rejected_full"
    c = reg["serve.requests"]
    assert c.value(outcome="completed") == 1
    assert c.value(outcome="rejected_too_long") == 1
    assert c.value(outcome="rejected_full") == 2


# -- latency metrics --------------------------------------------------------

def test_serve_histograms_match_numpy_percentiles(cfg, params, engine,
                                                  traffic):
    reg = MetricsRegistry()
    report = engine.run(list(traffic), registry=reg)
    for name, attr in (("serve.queue_wait_s", "queue_wait_s"),
                       ("serve.ttft_s", "ttft_s"),
                       ("serve.e2e_s", "e2e_s")):
        samples = [getattr(r, attr) for r in report.completed]
        h = reg[name]
        for q in (50, 95, 99):
            assert h.percentile(q) == pytest.approx(
                float(np.percentile(samples, q)), rel=0, abs=0)
        s = h.summary()
        assert s["count"] == len(samples)
        assert s["p50"] == h.percentile(50)


def test_histogram_percentiles_numpy_exact_random():
    from repro.obs.metrics import Histogram

    rng = np.random.RandomState(3)
    for n in (1, 2, 7, 100):
        h = Histogram(name="t")
        xs = rng.randn(n).tolist()
        for x in xs:
            h.observe(x, kind="a")
        for q in (0.0, 12.5, 50.0, 95.0, 99.0, 100.0):
            assert h.percentile(q, kind="a") == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12, abs=1e-15)
        snap = h.snapshot()["values"]["kind=a"]
        for k in ("p50", "p95", "p99"):
            assert k in snap
    assert Histogram(name="e").percentile(50) is None


# -- greedy_decode frontend regression (core/serving.py) --------------------

def test_greedy_decode_threads_frontend():
    fcfg = get_arch("internvl2-2b", smoke=True)
    # (param key, data seed) pinned so the frontend provably changes the
    # greedy token stream — the discriminating case for the regression
    fparams = TF.init_params(jax.random.key(0), fcfg)
    rng = np.random.RandomState(3)
    prompt = jnp.asarray(rng.randint(0, fcfg.vocab_size, size=(1, 8)),
                         jnp.int32)
    fe = jnp.asarray(rng.randn(1, fcfg.n_frontend_tokens, fcfg.frontend_dim),
                     jnp.bfloat16)
    n, max_len = 5, 8 + 5 + fcfg.n_frontend_tokens
    got = greedy_decode(fparams, fcfg, prompt, n, max_len, frontend=fe)
    # manual reference: prefill WITH the frontend, then decode steps
    cache = TF.init_cache(fcfg, 1, max_len)
    logits, cache = TF.prefill(fparams, fcfg, prompt, cache, fe)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    want = [tok]
    for _ in range(n - 1):
        logits, cache = TF.decode_step(fparams, fcfg, tok, cache)
        tok = jnp.argmax(logits, axis=-1)
        want.append(tok)
    assert np.array_equal(np.asarray(got),
                          np.asarray(jnp.concatenate(want, axis=1)))
    # and the frontend must actually influence decoding (the regression:
    # silently dropping it reproduced the text-only stream)
    without = greedy_decode(fparams, fcfg, prompt, n, max_len)
    assert not np.array_equal(np.asarray(got), np.asarray(without))


# -- checkpoint round trip --------------------------------------------------

def test_train_ckpt_out_roundtrips_into_serve(tmp_path):
    from repro.launch import train as train_cli

    ckpt = str(tmp_path / "ck")
    ds = train_cli.main([
        "--arch", "qwen3-14b", "--smoke", "--algo", "stl_sc",
        "--clients", "2", "--batch", "1", "--seq", "16",
        "--steps", "4", "--T1", "4", "--stages", "1",
        "--ckpt-out", ckpt])
    eng = ServeEngine.from_checkpoint(
        ckpt, scheduler=SchedulerConfig(n_slots=2, max_seq_len=32))
    assert eng.cfg.name == "qwen3-14b-smoke"
    # restored params are the consensus (client-mean) of the final state
    want = jax.tree.map(lambda p: np.asarray(p.mean(axis=0)),
                        ds.state["params"])
    got = jax.tree.map(np.asarray, eng.params)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert np.allclose(a.astype(np.float32), b.astype(np.float32),
                           atol=1e-6)
    reqs = [_req(0, 4, 3, arrival=1e-6), _req(1, 5, 2, arrival=2e-6)]
    for r in reqs:
        r.prompt[:] = r.id + 1
    report = eng.run(reqs, registry=MetricsRegistry())
    assert [r.outcome for r in report.records] == ["completed"] * 2
    ref = greedy_decode(eng.params, eng.cfg,
                        jnp.asarray(reqs[0].prompt[None, :]), 3, 32)
    assert report.records[0].tokens == np.asarray(ref)[0].tolist()
