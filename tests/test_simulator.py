"""Convergence behaviour of the N-client simulator (the paper's engine).

Small, fast problems only — the full paper-scale comparisons live in
benchmarks/. These tests pin the qualitative claims: STL-SGD^sc converges to
the optimum; Local SGD with admissible k matches SyncSGD's accuracy; the prox
surrogate (Alg. 3) is convex for a weakly-convex objective.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core import simulate
from repro.core.prox import prox_loss
from repro.data import make_binary_classification, partition_iid
from repro.models import logreg


@pytest.fixture(scope="module")
def problem():
    x, y = make_binary_classification(n=2048, d=32, seed=0)
    lam = 1e-2
    N = 4
    data = {k: jnp.asarray(v) for k, v in partition_iid(x, y, N, seed=0).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: logreg.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: logreg.full_objective(p, xj, yj, lam))
    p0 = logreg.init_params(None, 32)
    # near-exact optimum by GD
    p = p0
    g = jax.jit(jax.grad(eval_fn))
    for _ in range(2000):
        p = jax.tree.map(lambda a, b: a - 1.0 * b, p, g(p))
    fstar = float(eval_fn(p))
    return loss_fn, eval_fn, p0, data, fstar


def _run(problem, algo, **kw):
    loss_fn, eval_fn, p0, data, fstar = problem
    cfg = TrainConfig(algo=algo, eta1=kw.pop("eta1", 0.5),
                      T1=kw.pop("T1", 128), k1=kw.pop("k1", 1.0),
                      n_stages=kw.pop("n_stages", 6), iid=True,
                      batch_per_client=16, seed=0, **kw)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8,
                        max_rounds=kw.get("max_rounds", 3000))
    return hist, fstar


def test_stl_sc_converges(problem):
    hist, fstar = _run(problem, "stl_sc", k1=2.0, n_stages=7)
    assert hist[-1].value - fstar < 5e-3
    assert hist[-1].value < hist[0].value * 0.9


def test_local_sgd_matches_sync_accuracy(problem):
    h_sync, fstar = _run(problem, "sync", n_stages=8)
    h_local, _ = _run(problem, "local", k1=8.0, n_stages=8)
    # same iteration budget, local uses ~8x fewer rounds
    assert h_local[-1].round < h_sync[-1].round / 4
    assert abs(h_local[-1].value - h_sync[-1].value) < 2e-2


def test_crpsgd_runs_and_converges(problem):
    hist, fstar = _run(problem, "crpsgd", n_stages=6, batch_growth=1.05,
                       max_batch=64)
    assert hist[-1].value - fstar < 5e-2


def test_prox_loss_strong_convexification():
    """f(x) = -|x|²/2 is 1-weakly convex; f + (1/2γ)||x−c||² with γ⁻¹=2 is
    (γ⁻¹−1)-strongly convex → unique minimum, gradient monotone."""
    base = lambda p, b: -0.5 * jnp.sum(p["w"] ** 2)
    fn = prox_loss(base, gamma_inv=2.0)
    c = {"w": jnp.asarray([1.0, -2.0])}
    g = jax.grad(lambda p: fn(p, None, c))
    # gradient of (1/2)||x||²(γ⁻¹−1) shifted — check monotonicity along a line
    p1 = {"w": jnp.asarray([0.0, 0.0])}
    p2 = {"w": jnp.asarray([1.0, 1.0])}
    inner = jnp.sum((g(p2)["w"] - g(p1)["w"]) * (p2["w"] - p1["w"]))
    assert float(inner) > 0.0  # monotone gradient = convex


def test_stl_nc_option2_on_nonconvex():
    """Tiny non-convex problem (2-layer MLP, 2 clients): STL-SGD^nc-2 reduces
    the loss monotonically across stages."""
    rng = np.random.RandomState(0)
    X = jnp.asarray(rng.randn(256, 8).astype(np.float32))
    Y = jnp.asarray((rng.randn(256) > 0).astype(np.float32))
    data = {"x": X.reshape(2, 128, 8), "y": Y.reshape(2, 128)}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"])
        logit = h @ p["w2"]
        return jnp.mean(jnp.square(logit - b["y"]))

    p0 = {"w1": jnp.asarray(rng.randn(8, 16).astype(np.float32)) * 0.3,
          "w2": jnp.asarray(rng.randn(16).astype(np.float32)) * 0.3}
    eval_fn = lambda p: loss_fn(p, {"x": X, "y": Y})
    cfg = TrainConfig(algo="stl_nc2", eta1=0.2, T1=64, k1=2.0, n_stages=4,
                      iid=True, gamma_inv=0.5, batch_per_client=32, seed=0)
    hist = simulate.run(loss_fn, p0, data, cfg, eval_fn, eval_every=16)
    assert hist[-1].value < hist[0].value * 0.7
