"""SSD kernel sweeps vs the recurrent oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref
from repro.models.ssm import ssd_chunked


def _inputs(b, S, H, P, G, N, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = (jax.random.normal(ks[0], (b, S, H, P), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32)) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B = (jax.random.normal(ks[3], (b, S, G, N), jnp.float32) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, S, G, N), jnp.float32) * 0.3).astype(dtype)
    return x, dt, A, B, C


@pytest.mark.parametrize("shape", [
    (1, 128, 2, 32, 1, 16),
    (2, 256, 4, 64, 1, 32),
    (1, 256, 4, 64, 2, 32),   # grouped B/C (G=2)
])
@pytest.mark.parametrize("chunk", [64, 128])
def test_ssd_kernel_matches_recurrence(shape, chunk):
    x, dt, A, B, C = _inputs(*shape)
    yk, stk = ssd(x, dt, A, B, C, chunk=chunk, impl="interpret")
    yr, sr = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(sr),
                               atol=3e-4, rtol=3e-4)


def test_ssd_kernel_bf16():
    x, dt, A, B, C = _inputs(1, 128, 2, 64, 1, 32, dtype=jnp.bfloat16)
    yk, _ = ssd(x, dt, A, B, C, chunk=64, impl="interpret")
    yr, _ = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk, np.float32), np.asarray(yr),
                               atol=5e-2, rtol=5e-2)


def test_model_chunked_matches_recurrence_with_initial_state():
    x, dt, A, B, C = _inputs(2, 128, 2, 32, 1, 16)
    init = jax.random.normal(jax.random.key(9), (2, 2, 32, 16), jnp.float32) * 0.2
    ym, sm = ssd_chunked(x, dt, A, B, C, chunk=64, initial_state=init)
    yr, sr = ssd_ref(x, dt, A, B, C, initial_state=init)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yr), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(sm), np.asarray(sr), atol=3e-4, rtol=3e-4)
