"""Per-leaf streaming reduce: schedules, topology, ledger, execution.

The decisive invariants:
  * streaming is pure clock accounting — same config + seed produces
    bit-identical parameters and (round, objective) trajectories under
    the blocking and streaming upload schedules; only modeled wall-clock
    changes (and only shrinks);
  * a single-leaf model cannot overlap anything: its streaming and
    blocking round prices are identical;
  * the per-leaf comm ledger reconciles with the tree-level totals —
    bytes bit-exactly, modeled seconds to float-sum precision — for
    dense and int8 reducers, star and hierarchical topologies;
  * ``StreamingStar``'s per-leaf reduce and
    ``build_sync_step(streaming=True)``'s per-leaf round are bit-exact
    with their blocking counterparts (same per-leaf rng folds);
  * the StagewiseDriver accepts the streaming topology and carries the
    per-leaf ledger; asynchronous merging rejects streaming uploads.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.comm import NetworkModel, get_reducer
from repro.configs.base import TrainConfig
from repro.core import local_sgd as LS
from repro.core import simulate
from repro.data import make_binary_classification, partition_iid
from repro.engine import Star, StreamingStar, get_topology
from repro.models import logreg, mlp
from repro.runtime import (
    BlockingSchedule,
    ClientProcess,
    StreamingSchedule,
    get_schedule,
)
from repro.utils.tree import tree_broadcast_leading, tree_mean_leading


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def mlp_problem():
    x, y = make_binary_classification(n=512, d=96, seed=0)
    lam = 1e-3
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 8, seed=1).items()}
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    loss_fn = lambda p, b: mlp.loss_fn(p, b, lam)
    eval_fn = jax.jit(lambda p: mlp.full_objective(p, xj, yj, lam))
    return loss_fn, eval_fn, mlp.init_params(jax.random.key(42), 96), data


def _stream_cfg(**kw):
    base = dict(algo="sync", eta1=0.1, T1=16, n_stages=2,
                batch_per_client=16, seed=0,
                comm_latency_s=1e-4, comm_bandwidth_gbps=0.45,
                base_step_time_s=1e-3,
                straggler_frac=0.25, straggler_slowdown=2.0)
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# Upload schedule unit tests (pure clock arithmetic)
# ---------------------------------------------------------------------------

def _client(step_s=1e-3, alpha=1e-4, gbps=0.8):
    return ClientProcess(cid=0, rate=1.0, step_time_s=step_s,
                         network=NetworkModel(latency_s=alpha,
                                              bandwidth_gbps=gbps))


def test_blocking_schedule_events():
    c = _client()
    evs, fin = BlockingSchedule().round_events(c, 1.0, 2, [4000, 4000],
                                               [0.5, 0.5])
    assert [k for _, k, _ in evs] == ["compute_done", "arrival"]
    assert evs[0][0] == pytest.approx(1.0 + 2e-3)
    # arrival = compute_done + alpha + total_bytes / bandwidth
    assert fin == pytest.approx(1.0 + 2e-3 + 1e-4 + 8000 / 1e8)
    # dropped client: upload-only zero-delta answer from round start
    evs, fin = BlockingSchedule().round_events(c, 1.0, 2, [4000, 4000],
                                               [0.5, 0.5], active=False)
    assert [k for _, k, _ in evs] == ["arrival"]
    assert fin == pytest.approx(1.0 + 1e-4 + 8000 / 1e8)


def test_streaming_schedule_reverse_order_and_link_queue():
    """Leaves release in reverse order spread across the final step; the
    uplink is one serial stream (alpha once, leaves queue when the link is
    busy)."""
    c = _client()  # step 1 ms, alpha 0.1 ms, 1e8 B/s
    sched = StreamingSchedule()
    evs, fin = sched.round_events(c, 0.0, 2, [4000, 4000], [0.5, 0.5])
    kinds = [k for _, k, _ in evs]
    assert kinds == ["compute_done", "leaf_arrival", "leaf_arrival"]
    # leaf 1 (last layer) releases halfway through the final step
    # [1 ms, 2 ms] => ready 1.5 ms, +alpha +4000B/1e8 = 1.64 ms
    assert evs[1][2] == (1,)
    assert evs[1][0] == pytest.approx(1.5e-3 + 1e-4 + 4e-5)
    # leaf 0 releases at compute_done (2 ms), link already free => 2.04 ms
    assert evs[2][2] == (0,)
    assert evs[2][0] == pytest.approx(2e-3 + 4e-5)
    assert fin == pytest.approx(2e-3 + 4e-5)
    # vs blocking: 2 ms + 0.1 ms + 8e-5 s = 2.18 ms — streaming wins
    _, fin_b = BlockingSchedule().round_events(c, 0.0, 2, [4000, 4000],
                                               [0.5, 0.5])
    assert fin < fin_b

    # link-bound regime: big payloads queue back-to-back behind the stream
    evs, fin = sched.round_events(c, 0.0, 1, [40000, 40000], [0.5, 0.5])
    # leaf 1 ready 0.5 ms, fin 0.5e-3 + 1e-4 + 4e-4 = 1.0 ms; leaf 0 ready
    # 1 ms, link free 1.0 ms => fin 1.4 ms
    assert evs[-1][0] == pytest.approx(
        max(1e-3, 0.5e-3 + 1e-4 + 4e-4) + 4e-4)
    # dropped client streams its zero-delta leaves from round start
    evs, fin = sched.round_events(c, 2.0, 1, [4000, 4000], [0.5, 0.5],
                                  active=False)
    assert [k for _, k, _ in evs] == ["leaf_arrival", "leaf_arrival"]
    assert fin == pytest.approx(2.0 + 1e-4 + 8e-5)


def test_get_schedule_resolution():
    assert isinstance(get_schedule(None), BlockingSchedule)
    assert isinstance(get_schedule("blocking"), BlockingSchedule)
    assert isinstance(get_schedule("streaming"), StreamingSchedule)
    s = StreamingSchedule()
    assert get_schedule(s) is s
    with pytest.raises(ValueError, match="upload schedule"):
        get_schedule("bogus")


# ---------------------------------------------------------------------------
# Runtime: streaming is pure clock accounting
# ---------------------------------------------------------------------------

def test_streaming_bit_exact_trajectory_and_faster_clock(mlp_problem):
    loss_fn, eval_fn, p0, data = mlp_problem
    blk = runtime.run(loss_fn, p0, data, _stream_cfg(), eval_fn,
                      eval_every=8)
    stm = runtime.run(loss_fn, p0, data,
                      _stream_cfg(upload_schedule="streaming"), eval_fn,
                      eval_every=8)
    assert [(h.round, h.iteration, h.value) for h in blk.history] \
        == [(h.round, h.iteration, h.value) for h in stm.history]
    _tree_equal(blk.params, stm.params)
    # >= 4 leaves overlap: the modeled clock must strictly improve
    assert len(jax.tree.leaves(p0)) >= 4
    assert stm.wall_clock_s < blk.wall_clock_s
    # engine ledger (serial alpha-beta view) is schedule-independent
    assert stm.comm_bytes == blk.comm_bytes
    assert stm.comm_time_s == blk.comm_time_s


def test_streaming_single_leaf_cannot_overlap(golden_problem=None):
    """logreg has one leaf: its last local step releases the whole message
    at compute_done, so streaming and blocking clocks coincide exactly."""
    x, y = make_binary_classification(n=256, d=16, seed=3)
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=0).items()}
    loss_fn = lambda p, b: logreg.loss_fn(p, b, 1e-2)
    eval_fn = lambda p: logreg.full_objective(p, jnp.asarray(x),
                                              jnp.asarray(y), 1e-2)
    p0 = logreg.init_params(None, 16)
    cfg = _stream_cfg(T1=8, n_stages=1, batch_per_client=8)
    blk = runtime.run(loss_fn, p0, data, cfg, eval_fn)
    stm = runtime.run(loss_fn, p0, data,
                      dataclasses.replace(cfg, upload_schedule="streaming"),
                      eval_fn)
    assert stm.wall_clock_s == pytest.approx(blk.wall_clock_s)


def test_streaming_rejects_async(mlp_problem):
    loss_fn, eval_fn, p0, data = mlp_problem
    with pytest.raises(ValueError, match="streaming"):
        runtime.run(loss_fn, p0, data,
                    _stream_cfg(algo="local", k1=4.0, async_mode=True,
                                upload_schedule="streaming"), eval_fn)


def test_streaming_dropout_deterministic(mlp_problem):
    """Dropped clients stream their zero-delta leaves; same seed =>
    identical trace, params, and leaf arrivals for every leaf."""
    loss_fn, eval_fn, p0, data = mlp_problem
    cfg = _stream_cfg(upload_schedule="streaming", dropout_rate=0.25,
                      T1=8, n_stages=1)
    runs = [runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=4)
            for _ in range(2)]
    assert runs[0].trace == runs[1].trace
    _tree_equal(runs[0].params, runs[1].params)
    kinds = [e[1] for e in runs[0].trace]
    assert any(k == "dropout" for k in kinds)
    n_leaves = len(jax.tree.leaves(p0))
    # every client answers every round with all of its leaves, and every
    # per-leaf arrival stays attributable to its leaf index
    leaf_evs = [e for e in runs[0].trace if e[1] == "leaf_arrival"]
    assert len(leaf_evs) == 8 * n_leaves * kinds.count("merge")
    assert {e[3] for e in leaf_evs} == set(range(n_leaves))


# ---------------------------------------------------------------------------
# Per-leaf comm-ledger reconciliation
# ---------------------------------------------------------------------------

def test_legacy_reducer_without_leaf_bytes_still_runs_blocking():
    """A custom Reducer predating the per-leaf protocol (only reduce +
    message_bytes overridden) must keep working on blocking rounds — no
    leaf ledger — and be rejected with a clear error for streaming."""
    from repro.comm import Reducer
    from repro.utils.tree import tree_mean_leading as tml

    class LegacyMean(Reducer):
        name = "legacy"

        def reduce(self, stacked, state, rng):
            return tml(stacked), state

        def message_bytes(self, template):
            return sum(l.size * 4 for l in jax.tree.leaves(template))

    x, y = make_binary_classification(n=128, d=8, seed=0)
    data = {k: jnp.asarray(v)
            for k, v in partition_iid(x, y, 4, seed=0).items()}
    loss_fn = lambda p, b: logreg.loss_fn(p, b, 1e-2)
    eval_fn = lambda p: logreg.full_objective(p, jnp.asarray(x),
                                              jnp.asarray(y), 1e-2)
    p0 = logreg.init_params(None, 8)
    cfg = _stream_cfg(T1=4, n_stages=1, batch_per_client=8)
    res = runtime.run(loss_fn, p0, data, cfg, eval_fn, reducer=LegacyMean())
    assert res.rounds == 4
    assert res.leaf_ledger is None  # no per-leaf accounting available
    with pytest.raises(ValueError, match="leaf_message_bytes"):
        runtime.run(loss_fn, p0, data,
                    dataclasses.replace(cfg, upload_schedule="streaming"),
                    eval_fn, reducer=LegacyMean())


def test_leaf_message_bytes_sum_to_message_bytes(mlp_problem):
    _, _, p0, _ = mlp_problem
    for spec in ("dense", "int8", "int4", "topk", "staleness",
                 "staleness-int8"):
        red = get_reducer(spec)
        lb = red.leaf_message_bytes(p0)
        assert len(lb) == len(jax.tree.leaves(p0))
        assert sum(lb) == red.message_bytes(p0)


@pytest.mark.parametrize("reducer", ["dense", "int8"])
@pytest.mark.parametrize("topology", ["star", "hier"])
def test_leaf_ledger_reconciles_with_tree_totals(mlp_problem, reducer,
                                                 topology):
    """Streaming per-leaf totals (bytes and modeled seconds, summed over
    leaves and hops) equal the blocking tree-level engine ledger — dense
    and int8, flat star and hierarchical."""
    loss_fn, eval_fn, p0, data = mlp_problem
    kw = dict(reducer=reducer, topology=topology, n_pods=2,
              T1=8, n_stages=1)
    blk = runtime.run(loss_fn, p0, data, _stream_cfg(**kw), eval_fn,
                      eval_every=4)
    stm = runtime.run(
        loss_fn, p0, data,
        _stream_cfg(upload_schedule="streaming", **kw), eval_fn,
        eval_every=4)
    assert stm.leaf_ledger, "streaming run must carry the per-leaf ledger"
    n_hops = 2 if topology == "hier" else 1
    assert len(stm.leaf_ledger) == n_hops * len(jax.tree.leaves(p0))
    # bytes reconcile bit-exactly (integer per-leaf formulas)
    assert sum(l["bytes"] for l in stm.leaf_ledger) == blk.comm_bytes
    # modeled seconds reconcile to float-sum precision
    t = math.fsum(l["time_s"] for l in stm.leaf_ledger)
    assert t == pytest.approx(blk.comm_time_s, rel=1e-12)
    # and the trajectory is reducer/topology-faithful but schedule-free
    assert [(h.round, h.value) for h in blk.history] \
        == [(h.round, h.value) for h in stm.history]


# ---------------------------------------------------------------------------
# StreamingStar topology (execution half)
# ---------------------------------------------------------------------------

def test_streaming_star_bit_exact_with_star():
    rng = jax.random.key(0)
    stacked = {"a": jax.random.normal(rng, (4, 33)),
               "b": {"c": jax.random.normal(jax.random.fold_in(rng, 1),
                                            (4, 5, 7)),
                     "d": jax.random.normal(jax.random.fold_in(rng, 2),
                                            (4, 11))}}
    for spec in ("dense", "int8", "topk"):
        star = Star(reducer=get_reducer(spec))
        stream = StreamingStar(reducer=get_reducer(spec))
        c1, s1 = star.reduce(stacked, star.init_state(stacked),
                             jax.random.key(7))
        c2, s2 = stream.reduce(stacked, stream.init_state(stacked),
                               jax.random.key(7))
        _tree_equal(c1, c2)
        _tree_equal(s1, s2)
        # inherited cost model: streaming and blocking ledgers reconcile
        assert stream.round_bytes(stacked, 4) == star.round_bytes(stacked, 4)
        lc = stream.leaf_costs(stacked, 4)
        assert sum(l.bytes for l in lc) == stream.round_bytes(stacked, 4)
        assert math.fsum(l.time_s for l in lc) \
            == pytest.approx(stream.round_time(stacked, 4), rel=1e-12)
    assert isinstance(get_topology("streaming"), StreamingStar)
    assert get_topology("streaming").name == "streaming-star"


def test_leaf_costs_reconcile_with_downlink_billed():
    """count_downlink links bill the dense broadcast too; the per-leaf
    ledger must mirror round_bytes or streaming runs under-report."""
    tmpl = {"a": jnp.zeros((33,)), "b": jnp.zeros((5, 7))}
    net = NetworkModel(latency_s=1e-3, bandwidth_gbps=1.0,
                       count_downlink=True)
    for spec in ("dense", "int8"):
        topo = StreamingStar(reducer=get_reducer(spec), network=net)
        lc = topo.leaf_costs(tmpl, 4)
        assert sum(l.bytes for l in lc) == topo.round_bytes(tmpl, 4)
        assert math.fsum(l.time_s for l in lc) \
            == pytest.approx(topo.round_time(tmpl, 4), rel=1e-12)


def test_simulator_streaming_topology_matches_star(mlp_problem):
    loss_fn, eval_fn, p0, data = mlp_problem
    cfg = _stream_cfg(algo="stl_sc", T1=8, k1=2.0, n_stages=2,
                      reducer="int8")
    h_star = simulate.run(loss_fn, p0, data, cfg, eval_fn, topology="star")
    h_stream = simulate.run(loss_fn, p0, data, cfg, eval_fn,
                            topology="streaming")
    assert [(h.round, h.value) for h in h_star] \
        == [(h.round, h.value) for h in h_stream]


# ---------------------------------------------------------------------------
# build_sync_step(streaming=True) + StagewiseDriver
# ---------------------------------------------------------------------------

def _driver_state(n=4, d=16, seed=0):
    key = jax.random.key(seed)
    params = {"w1": jax.random.normal(key, (d, d)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (d,))}
    return {"params": tree_broadcast_leading(params, n),
            "opt": {"mu": jax.tree.map(jnp.zeros_like,
                                       tree_broadcast_leading(params, n))},
            "step": jnp.zeros((), jnp.int32)}


def _perturb(state, seed=9):
    key = jax.random.key(seed)
    params = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(
            jax.random.fold_in(key, x.shape[-1]), x.shape),
        state["params"])
    return dict(state, params=params)


@pytest.mark.parametrize("reducer", [None, "int8"])
def test_build_sync_step_streaming_bit_exact(reducer):
    state = _perturb(_driver_state())
    blocking = jax.jit(LS.build_sync_step(reducer))
    streaming = jax.jit(LS.build_sync_step(reducer, streaming=True))
    out_b, out_s = blocking(state), streaming(state)
    assert set(out_b.keys()) == set(out_s.keys())  # same state contract
    _tree_equal(out_b["params"], out_s["params"])
    if reducer is not None:
        _tree_equal(out_b["comm"], out_s["comm"])
        # second round threads the comm state identically
        _tree_equal(blocking(out_b)["params"], streaming(out_s)["params"])


def test_driver_accepts_streaming_topology_and_carries_leaf_ledger():
    from repro.core.stl_sgd import StagewiseDriver

    d = 16

    def toy_loss(params, batch, eta):  # pragma: no cover - signature only
        raise NotImplementedError

    def train_step(state, batch, eta):
        g = jax.tree.map(lambda x: 0.01 * x, state["params"])
        return dict(state, params=jax.tree.map(jnp.subtract,
                                               state["params"], g),
                    step=state["step"] + 1), {"loss": jnp.zeros(())}

    sync_step = LS.build_sync_step("int8", streaming=True)
    tcfg = TrainConfig(algo="local", T1=8, k1=2.0, n_stages=1,
                       topology="streaming")
    drv = StagewiseDriver(tcfg, train_step, sync_step)
    assert drv.streaming
    assert drv.reducer.name == "int8"
    batches = iter([{"x": None}] * 64)
    ds = drv.run(_perturb(_driver_state(d=d)), batches)
    assert ds.rounds_total == 4
    assert ds.leaf_ledger, "streaming driver must carry the per-leaf ledger"
    assert sum(l["bytes"] for l in ds.leaf_ledger) == ds.comm_bytes_total
    assert math.fsum(l["time_s"] for l in ds.leaf_ledger) \
        == pytest.approx(ds.comm_time_s, rel=1e-12)
    # a streaming-tagged sync_step implies the per-leaf round even under
    # a plain "star" config
    drv2 = StagewiseDriver(TrainConfig(algo="local", T1=4, k1=2.0,
                                       n_stages=1), train_step, sync_step)
    assert drv2.streaming
    # a hierarchical config still refuses a *flat* sync step (streaming or
    # not): the ledger would price an inter-pod hop the round never crosses
    with pytest.raises(ValueError, match="build_sync_step"):
        StagewiseDriver(TrainConfig(algo="local", topology="hier"),
                        train_step, sync_step)
    with pytest.raises(ValueError, match="build_sync_step"):
        StagewiseDriver(TrainConfig(algo="local", topology="streaming-hier"),
                        train_step, sync_step)
