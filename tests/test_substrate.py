"""Substrate tests: checkpointing, data pipeline, optimizers, CNNs, hlo parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import make_binary_classification, make_multiclass_images, make_token_stream
from repro.data.partition import partition_paper
from repro.models import cnn
from repro.optim import adamw_init, adamw_update, make_optimizer, sgd_init, sgd_update


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "nested": {"b": jnp.ones((4,), jnp.float32)},
            "lst": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}
    save_checkpoint(str(tmp_path), 42, tree, {"stage": 3, "k": 8})
    assert latest_step(str(tmp_path)) == 42
    restored, meta = load_checkpoint(str(tmp_path), tree)
    assert meta == {"stage": 3, "k": 8}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"w": jnp.zeros((4,))})


def test_paper_partition_noniid_skew():
    """Label-sorted dealing must create skewed class distributions (s=0)."""
    x, y = make_multiclass_images(n=2000, n_classes=10)
    out = partition_paper(x, y, 8, iid_percent=0.0, seed=0)
    # each client's share should be dominated by few classes
    dominances = []
    for c in range(8):
        _, counts = np.unique(out["y"][c], return_counts=True)
        dominances.append(counts.max() / counts.sum())
    assert np.mean(dominances) > 0.5
    # while s=100 gives near-uniform
    out_iid = partition_paper(x, y, 8, iid_percent=100.0, seed=0)
    dom_iid = []
    for c in range(8):
        _, counts = np.unique(out_iid["y"][c], return_counts=True)
        dom_iid.append(counts.max() / counts.sum())
    assert np.mean(dom_iid) < 0.3


def test_token_stream_noniid_heads_differ():
    shards = make_token_stream(5000, 100, 4, seed=0, non_iid=True)
    heads = [np.bincount(s, minlength=100).argmax() for s in shards]
    assert len(set(heads)) > 1


def test_sgd_momentum_update():
    p = {"w": jnp.ones((4,))}
    st = sgd_init(p)
    g = {"w": jnp.full((4,), 2.0)}
    p1, st1 = sgd_update(p, g, st, eta=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - 0.2)
    p2, st2 = sgd_update(p1, g, st1, eta=0.1, momentum=0.9)
    # m2 = 0.9*2 + 2 = 3.8 → p2 = 0.8 - 0.38
    np.testing.assert_allclose(np.asarray(p2["w"]), 0.8 - 0.38, rtol=1e-6)


def test_adamw_decreases_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(p)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, st = adamw_update(p, g, st, eta=0.05)
    assert float(loss(p)) < 0.1


@pytest.mark.parametrize("net", ["resnet18", "vgg16"])
def test_cnn_forward_and_grad(net):
    rng = jax.random.key(0)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    y = jnp.asarray([1, 3])
    if net == "resnet18":
        params, strides = cnn.init_resnet18(rng, width=8)
        fwd = lambda p: cnn.apply_resnet18(p, strides, x)
    else:
        params = cnn.init_vgg16(rng, width=8)
        fwd = lambda p: cnn.apply_vgg16(p, x)
    logits = fwd(params)
    assert logits.shape == (2, 10)
    assert bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda p: cnn.cross_entropy(fwd(p), y))(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


def test_hlo_parser_on_synthetic_module():
    from repro.launch.hlo_analysis import parse_collectives_nested

    hlo = """HloModule test, is_scheduled=true

%cond (arg: (s32[])) -> pred[] {
  %iv = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (arg: (s32[])) -> (s32[]) {
  %x = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  ROOT %t = (s32[]) tuple(%iv)
}

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %w = (s32[]) while(%init), condition=%cond, body=%body
  %ag = f32[16,16]{1,0} all-gather(%p), replica_groups={{0,2},{1,3}}, dimensions={0}
  ROOT %r = f32[16,16]{1,0} copy(%ag)
}
"""
    colls = parse_collectives_nested(hlo, {"data": 2, "model": 2})
    kinds = sorted(c["kind"] for c in colls)
    assert kinds == ["all-gather", "all-reduce"]
    ar = next(c for c in colls if c["kind"] == "all-reduce")
    ag = next(c for c in colls if c["kind"] == "all-gather")
    assert ar["trip_mult"] == 5.0          # inside the while: ×trip count
    assert ar["axes"] == ["model"]         # groups {0,1} vary the minor axis
    assert ag["trip_mult"] == 1.0
    assert ag["axes"] == ["data"]          # groups {0,2} vary the major axis
    assert ar["bytes"] == 8 * 16 * 4 * 5
