"""Time-series telemetry, SLO monitoring and modeled-vs-measured profiling.

The decisive invariants of the trajectory half of ``repro.obs``:
  * the clock-domain guard is strict: re-registering a series name on a
    different clock (or an unknown clock) raises ``ClockDomainError``
    instead of silently interleaving timelines;
  * same (config, seed) ⇒ bit-identical series fingerprints across
    repeated runs, for the synchronous and asynchronous event runtimes,
    and between traced and untraced engine runs (the modeled cursor is
    one arithmetic path either way);
  * series export as Perfetto counter tracks whose timestamps align with
    the span timestamps of the same clock's process;
  * the SLO monitor turns windowed aggregates into breach intervals:
    synthetic breaches are detected, recovery closes them, an open
    breach at trace end reads as saturation, and intervals export as
    ``slo_breach`` spans on the virtual clock;
  * ``ProfileSession`` reconciles: every profiled span carries both
    modeled and measured seconds, span durations equal the recorded
    measured times, and wrapping never hides ``build_sync_step`` tags;
  * histogram percentiles are numpy-exact below ``cap`` and degrade to a
    flagged, deterministic reservoir above it;
  * ``read_jsonl`` round-trips ``write_jsonl`` span logs;
  * ``StructuredLogger.limit`` samples/rate-limits without silent drops.
"""
import io
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.core.local_sgd import build_sync_step, sync_step_tags
from repro.core.stl_sgd import StagewiseDriver, driver_state
from repro.obs import (
    MODELED,
    VIRTUAL,
    WALL,
    ClockDomainError,
    ProfileSession,
    Series,
    SeriesRegistry,
    SLOMonitor,
    SLOTarget,
    Tracer,
    format_skew_table,
    read_jsonl,
    serve_slo_targets,
    span_record,
    to_chrome_trace,
    write_jsonl,
)
from repro.obs import metrics as obs_metrics
from repro.obs import series as obs_series
from repro.utils.logging import StructuredLogger

from tests.test_obs import _cfg, problem  # noqa: F401 (fixture)


@pytest.fixture(autouse=True)
def _fresh_registries():
    obs_metrics.reset()
    obs_series.reset()
    yield
    obs_metrics.reset()
    obs_series.reset()


# ---------------------------------------------------------------------------
# Series primitives: clock guard, windowed views, bounded memory
# ---------------------------------------------------------------------------

def test_clock_domain_guard_raises():
    reg = SeriesRegistry()
    s = reg.series("q.depth", VIRTUAL, unit="requests")
    assert reg.series("q.depth", VIRTUAL) is s          # idempotent
    with pytest.raises(ClockDomainError):
        reg.series("q.depth", MODELED)
    with pytest.raises(ClockDomainError):
        reg.add(Series("q.depth", WALL))
    with pytest.raises(ClockDomainError):
        Series("bogus", "gpu-clock")


def test_series_sorts_lazily_and_stably():
    s = Series("lat", VIRTUAL)
    for t, v in [(3.0, 30.0), (1.0, 10.0), (2.0, 20.0), (1.0, 11.0)]:
        s.record(t, v)
    assert s.samples() == [(1.0, 10.0), (1.0, 11.0), (2.0, 20.0),
                           (3.0, 30.0)]
    assert s.last() == (3.0, 30.0)


def test_series_max_samples_drops_deterministically():
    s = Series("bounded", VIRTUAL, max_samples=3)
    for i in range(5):
        s.record(float(i), float(i))
    assert len(s) == 3
    assert s.values() == [0.0, 1.0, 2.0]                # keep-first
    assert s.dropped == 2
    assert s.snapshot()["summary"]["dropped"] == 2
    assert s.fingerprint()[-1] == 2                     # drops are identity


def test_windowed_views_match_brute_force():
    ts = [float(i) for i in range(10)]
    vs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0, 3.0]
    s = Series("x", VIRTUAL)
    for t, v in zip(ts, vs):
        s.record(t, v)
    w = 3.0
    mean = s.window_mean(w)
    p95 = s.window_percentile(95, w)
    assert mean.clock == p95.clock == VIRTUAL
    for (t, m), (_, p) in zip(mean.samples(), p95.samples()):
        window = [v for tt, v in zip(ts, vs) if t - w < tt <= t]
        assert m == pytest.approx(sum(window) / len(window), rel=1e-12)
        assert p == pytest.approx(np.percentile(window, 95), rel=1e-12)
    # min_count delays percentile emission until the window fills
    late = s.window_percentile(50, w, min_count=3)
    assert late.times() == ts[2:]


def test_rate_of_cumulative_counter():
    s = Series("tokens", VIRTUAL, unit="tokens")
    for i in range(8):
        s.record(float(i), 2.0 * i)
    r = s.rate(4.0)
    assert r.unit == "tokens/s"
    assert r.times() == [float(i) for i in range(1, 8)]  # t=0: zero-span
    assert all(v == pytest.approx(2.0) for v in r.values())


# ---------------------------------------------------------------------------
# Determinism: same seed ⇒ identical series, traced or not
# ---------------------------------------------------------------------------

def _series_run(problem, cfg, tracer=None):
    loss_fn, eval_fn, p0, data = problem
    reg = SeriesRegistry()
    runtime.run(loss_fn, p0, data, cfg, eval_fn, eval_every=8,
                tracer=tracer, series=reg)
    return reg


@pytest.mark.parametrize("kw,expected", [
    (dict(), ["comm.round_bytes", "comm.round_time_s", "comm.cum_bytes",
              "train.stage_bytes", "runtime.active_clients",
              "runtime.round_time_s"]),
    (dict(async_mode=True, straggler_frac=0.25, straggler_slowdown=2.0),
     ["runtime.active_clients", "runtime.inflight_merges",
      "runtime.merge_staleness"]),
], ids=["sync", "async"])
def test_same_seed_same_series(problem, kw, expected):
    cfg = _cfg(**kw)
    a = _series_run(problem, cfg)
    b = _series_run(problem, cfg)
    for name in expected:
        assert name in a, f"missing series {name}: {a.names()}"
        assert len(a[name]) > 0
    assert a.fingerprint() == b.fingerprint()


def test_engine_series_identical_traced_vs_untraced(problem):
    cfg = _cfg()
    tr = Tracer()
    traced = _series_run(problem, cfg, tracer=tr)
    untraced = _series_run(problem, cfg)
    assert traced.fingerprint() == untraced.fingerprint()
    # the comm.* sample times ARE the round-span end times: one
    # arithmetic path moves the modeled cursor whether or not spans exist
    rounds = tr.find("round", clock=MODELED)
    s_time = traced["comm.round_time_s"]
    assert s_time.clock == MODELED
    assert s_time.times() == [r.t1 for r in rounds]
    assert s_time.values() == [r.t1 - r.t0 for r in rounds]
    # cumulative bytes is the running sum of per-round bytes, bit-exactly
    cum = traced["comm.cum_bytes"].values()
    per = traced["comm.round_bytes"].values()
    assert cum == [float(sum(per[:i + 1])) for i in range(len(per))]


def test_stage_objective_vs_bytes_curve(problem):
    reg = _series_run(problem, _cfg())
    obj, byt = reg["train.stage_objective"], reg["train.stage_bytes"]
    assert obj.clock == byt.clock == MODELED
    assert len(obj) == len(byt) == 2                    # one per stage
    assert obj.times() == byt.times()                   # same boundaries
    assert byt.values() == sorted(byt.values())         # bytes accumulate


# ---------------------------------------------------------------------------
# Counter tracks: series render as "C" events aligned with spans
# ---------------------------------------------------------------------------

def test_counter_tracks_align_with_spans(problem):
    tr = Tracer(run_id="ct")
    reg = _series_run(problem, _cfg(), tracer=tr)
    trace = to_chrome_trace(tr, series=reg)
    counters = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    by_name = {}
    for e in counters:
        by_name.setdefault(e["name"], []).append(e)
    assert set(reg.names()) <= set(by_name)
    # every sample became one C event at its own (µs) timestamp, in the
    # process of its clock — modeled pid 2 here, same as the round spans
    s = reg["comm.round_time_s"]
    evs = by_name["comm.round_time_s"]
    assert [e["ts"] for e in evs] == [t * 1e6 for t in s.times()]
    assert [e["args"]["value"] for e in evs] == s.values()
    round_ev = next(e for e in trace["traceEvents"]
                    if e.get("ph") == "X" and e["name"] == "round"
                    and e["args"]["clock"] == MODELED)
    assert evs[0]["pid"] == round_ev["pid"]
    assert json.dumps(trace)                            # serializable


def test_wall_series_rebased_like_wall_spans():
    tr = Tracer(run_id="w")
    tr.add("step", 100.0, 101.0, clock=WALL, track="host")
    s = Series("host.rss", WALL, unit="B")
    s.record(100.5, 7.0)
    trace = to_chrome_trace(tr, series=[s])
    c = next(e for e in trace["traceEvents"] if e["ph"] == "C")
    x = next(e for e in trace["traceEvents"] if e["ph"] == "X")
    assert x["ts"] == 0.0                               # rebased to wall0
    assert c["ts"] == pytest.approx(0.5e6)


# ---------------------------------------------------------------------------
# SLO monitor: synthetic breaches, recovery, saturation
# ---------------------------------------------------------------------------

def _ttft_series(reg, samples):
    s = reg.series("serve.ttft_s", VIRTUAL, unit="s")
    for t, v in samples:
        s.record(t, v)
    return s


def test_slo_detects_breach_and_recovery():
    reg = SeriesRegistry()
    good = [(float(t), 1.0) for t in range(6)]
    bad = [(float(t), 20.0) for t in range(10, 14)]
    _ttft_series(reg, good + bad + [(20.0, 1.0)])
    targets = serve_slo_targets(1.0, window_steps=4.0, min_count=1)
    mon = SLOMonitor(targets)
    breaches = mon.evaluate(reg)
    assert [b.target for b in breaches] == ["ttft_p95"]
    b = breaches[0]
    assert (b.t0, b.t1, b.worst, b.open) == (10.0, 13.0, 20.0, False)
    assert mon.time_to_breach() == 10.0
    assert mon.breach_seconds() == 3.0
    assert not mon.saturated()                          # recovered by t=20
    tr = Tracer()
    mon.emit_spans(tr)
    span = tr.find("slo_breach", clock=VIRTUAL)[0]
    assert (span.t0, span.t1) == (10.0, 13.0)
    assert span.attrs["target"] == "ttft_p95"
    assert span.attrs["open"] is False


def test_slo_open_breach_reads_as_saturated():
    reg = SeriesRegistry()
    _ttft_series(reg, [(float(t), 1.0) for t in range(4)]
                 + [(float(t), 50.0) for t in range(10, 14)])
    mon = SLOMonitor(serve_slo_targets(1.0, window_steps=4.0, min_count=1))
    mon.evaluate(reg)
    assert mon.saturated()
    assert mon.breaches[-1].open


def test_slo_clean_run_and_partial_telemetry():
    reg = SeriesRegistry()
    _ttft_series(reg, [(float(t), 1.0) for t in range(8)])
    # e2e/tokens series absent: targets over them contribute nothing
    mon = SLOMonitor(serve_slo_targets(1.0, tok_s_floor=1.0))
    assert mon.evaluate(reg) == []
    assert mon.time_to_breach() is None
    assert mon.breach_seconds() == 0.0
    assert not mon.saturated()
    assert mon.summary()["n_breaches"] == 0


def test_slo_throughput_floor_breaches_from_below():
    reg = SeriesRegistry()
    tok = reg.series("serve.tokens_total", VIRTUAL, unit="tokens")
    for i in range(8):
        tok.record(float(i), float(i))                  # 1 token/s
    targets = serve_slo_targets(1.0, window_steps=4.0,
                                tok_s_floor=10.0)
    mon = SLOMonitor(targets)
    mon.evaluate(reg)
    floor = [b for b in mon.breaches if b.target == "tok_s_min"]
    assert floor and floor[-1].open                     # never recovers
    assert floor[0].worst == pytest.approx(1.0)


def test_slo_targets_scale_with_decode_step():
    fast = serve_slo_targets(1e-6)
    slow = serve_slo_targets(1e-3)
    for f, s in zip(fast, slow):
        assert s.threshold == pytest.approx(1e3 * f.threshold)
        assert s.window_s == pytest.approx(1e3 * f.window_s)
    with pytest.raises(ValueError):
        SLOTarget("bad", "serve.ttft_s", "p42", 1.0, 1.0)


# ---------------------------------------------------------------------------
# ProfileSession: modeled-vs-measured reconciliation on a toy driver
# ---------------------------------------------------------------------------

def _toy_driver(profile):
    def train_fn(state, batch, eta):
        return dict(state, step=state["step"] + 1), {"loss": 0.5}

    sync_fn = lambda state: state
    tcfg = _cfg(T1=4, n_stages=2)
    train_w = profile.wrap(train_fn, "train_step", 1e-3)
    sync_w = profile.wrap(sync_fn, "sync_step", lambda *a, **k: 2e-3)
    return StagewiseDriver(tcfg, train_w, sync_w)


def test_profile_skew_table_reconciles():
    import itertools

    prof = ProfileSession()
    driver = _toy_driver(prof)
    state = driver_state({"w": jnp.ones((8,), jnp.float32)}, 4)
    with prof:
        ds = driver.run(state, itertools.repeat(None), max_iters=12)
    assert ds.iters_total == 12
    rows = {r["name"]: r for r in prof.skew_table()}
    assert set(rows) == {"train_step", "sync_step"}
    # every profiled call carries BOTH timelines; totals reconcile
    assert rows["train_step"]["calls"] == 12
    assert rows["train_step"]["modeled_s"] == pytest.approx(12e-3)
    assert rows["sync_step"]["modeled_s"] == pytest.approx(
        rows["sync_step"]["calls"] * 2e-3)
    for r in rows.values():
        assert r["measured_s"] >= 0.0
        assert r["skew"] == r["measured_s"] / r["modeled_s"]
    # emit_spans: wall-clock profile.<name> spans, durations equal to the
    # measured seconds bit-exactly, attrs carrying both timelines
    tr = Tracer()
    prof.emit_spans(tr)
    spans = tr.find("profile.train_step") + tr.find("profile.sync_step")
    assert len(spans) == len(prof.records)
    for sp in spans:
        assert sp.clock == WALL
        assert "modeled_s" in sp.attrs and "measured_s" in sp.attrs
        assert sp.key()[6:8] == (None, None)            # wall ts excluded
    assert math.fsum(sp.t1 - sp.t0 for sp in spans) \
        == math.fsum(r.measured_s for r in prof.records)
    table = format_skew_table(prof.skew_table())
    assert "train_step" in table and "skew" in table
    assert format_skew_table([]) == "(no profiled steps)"


def test_profile_wrap_preserves_sync_step_tags():
    import jax

    raw = build_sync_step("int8")
    prof = ProfileSession()
    wrapped = prof.wrap(jax.jit(raw), "sync_step", 1e-3)
    assert sync_step_tags(wrapped) == sync_step_tags(raw)
    assert sync_step_tags(wrapped)["reducer"] is not None


def test_profile_session_without_logdir_is_harmless():
    prof = ProfileSession()                             # no jax.profiler
    with prof:
        out = prof.step("f", 0.5, lambda a, b: a + b, 2, 3)
    assert out == 5
    (r,) = prof.records
    assert r.modeled_s == 0.5 and r.t1 >= r.t0
    assert r.measured_s == r.t1 - r.t0


# ---------------------------------------------------------------------------
# Histogram reservoir: exact below cap, flagged + deterministic above
# ---------------------------------------------------------------------------

def test_histogram_exact_below_cap():
    rng = np.random.RandomState(0)
    vals = rng.exponential(size=200).tolist()
    h = obs_metrics.registry().histogram("lat.exact", unit="s")
    for v in vals:
        h.observe(v)
    s = h.summary()
    assert s["approx"] is False
    assert s["count"] == 200
    for q in (50, 95, 99):
        assert s[f"p{q}"] == pytest.approx(np.percentile(vals, q),
                                           rel=1e-12)


def test_histogram_reservoir_above_cap():
    vals = [float(i) for i in range(1000)]

    def fill(reg):
        h = reg.histogram("lat.capped", unit="s", cap=16)
        for v in vals:
            h.observe(v)
        return h

    h1, h2 = fill(obs_metrics.MetricsRegistry()), \
        fill(obs_metrics.MetricsRegistry())
    s = h1.summary()
    assert s["approx"] is True
    assert s["count"] == 1000 and s["max"] == 999.0     # stats stay exact
    assert s["sum"] == pytest.approx(sum(vals))
    assert len(h1.samples[()]) == 16
    # the reservoir is seeded per (metric, label set): runs agree bit-wise
    assert h1.samples[()] == h2.samples[()]


def test_serve_ledger_pins_cap_above_sample_counts():
    from repro.serve.ledger import LATENCY_SAMPLE_CAP

    assert LATENCY_SAMPLE_CAP >= 4096                   # table6 stays exact


# ---------------------------------------------------------------------------
# JSONL round-trip
# ---------------------------------------------------------------------------

def test_read_jsonl_round_trips(tmp_path):
    tr = Tracer(run_id="rt")
    rid = tr.begin("round", 0.0, clock=MODELED, track="round",
                   attrs={"k": 2})
    tr.add("reduce", 0.0, 1.5, clock=MODELED, track="hop/0",
           attrs={"bytes": 4096, "reducer": "int8"})
    tr.end(rid, 2.0)
    tr.add("merge", 0.25, 0.5, clock=VIRTUAL, track="server",
           attrs={"staleness": 0.125})
    path = str(tmp_path / "spans.jsonl")
    write_jsonl(tr, path)
    back = read_jsonl(path)
    assert [span_record(s) for s in back] \
        == [span_record(s) for s in tr.spans]
    assert [s.key() for s in back] == [s.key() for s in tr.spans]
    # a re-exported trace is identical to the original's
    assert to_chrome_trace(back) == to_chrome_trace(tr.spans)


# ---------------------------------------------------------------------------
# Logger sampling / rate limiting: never silent
# ---------------------------------------------------------------------------

def test_logger_every_n_counts_drops():
    buf = io.StringIO()
    log = StructuredLogger("lim", stream=buf, level="debug").limit(every_n=3)
    recs = [log.info("tick", i=i) for i in range(7)]
    emitted = [r for r in recs if r is not None]
    assert [r["i"] for r in emitted] == [0, 3, 6]
    # drops surface on the NEXT emitted record, cumulatively since last
    assert "dropped" not in emitted[0]
    assert emitted[1]["dropped"] == emitted[2]["dropped"] == 2
    assert log.dropped_total == 4
    assert obs_metrics.registry()["log.dropped_lines"].value(logger="lim") \
        == 4
    assert len(buf.getvalue().strip().splitlines()) == 3
    # warnings bypass the limiter and don't consume the sample sequence
    assert log.warning("uhoh") is not None
    assert log.info("tick", i=7) is None                # 8th info: dropped


def test_logger_max_per_s_on_virtual_clock():
    class FakeClock:
        now = 0.0

    clk = FakeClock()
    buf = io.StringIO()
    log = (StructuredLogger("rps", stream=buf, level="debug")
           .bind_clock(clk).limit(max_per_s=2.0))     # 0.5 s buckets
    out = []
    for t in (0.0, 0.1, 0.2, 0.6, 0.7, 2.0):
        clk.now = t
        out.append(log.info("ev", t=t))
    assert [r["t"] for r in out if r] == [0.0, 0.6, 2.0]
    assert out[3]["dropped"] == 2
    assert all(r is None or r["virtual_time_s"] == r["t"] for r in out)
    # limit() with no args clears both limiters
    log.limit()
    assert log.info("ev", t=99.0) is not None


def test_logger_unlimited_by_default():
    buf = io.StringIO()
    log = StructuredLogger("free", stream=buf, level="debug")
    assert all(log.info("ev", i=i) is not None for i in range(5))
    assert log.dropped_total == 0
