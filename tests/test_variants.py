"""Beyond-paper variant correctness: int8 KV cache, grouped MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.attention import _dequant, _quant


def test_quant_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (4, 8, 64), jnp.float32) * 3.0
    q, s = _quant(x)
    back = _dequant(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 1.0 / 120  # 8-bit symmetric quantization bound


def test_kv_int8_decode_close_to_fp():
    cfg = get_arch("musicgen-medium", smoke=True).replace(dtype="float32")
    cfg_q = cfg.replace(kv_quant=True)
    params = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    fe = jax.random.normal(
        jax.random.key(2), (B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)

    def decode_all(c):
        cache = T.init_cache(c, B, S + c.n_frontend_tokens, "float32")
        lg, cache = T.prefill(params, c, toks[:, :8], cache, fe)
        outs = [lg[:, -1:]]
        for i in range(8, S):
            lg, cache = T.decode_step(params, c, toks[:, i : i + 1], cache)
            outs.append(lg)
        return jnp.concatenate(outs, axis=1)

    fp = decode_all(cfg)
    q = decode_all(cfg_q)
    rel = float(jnp.max(jnp.abs(fp - q)) / jnp.max(jnp.abs(fp)))
    assert rel < 2e-2, rel


def test_grouped_moe_matches_flat_when_dropless():
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True).replace(dtype="float32")
    from repro.models.moe import _moe_pool, apply_moe, init_moe

    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (3, 16, cfg.d_model), jnp.float32)
    y_grouped, aux_g = apply_moe(params, cfg, x)
    # flat pool (all tokens together): dropless capacity → same expert outputs
    y_flat, aux_f = _moe_pool(params, cfg.moe, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(y_grouped.reshape(-1, cfg.d_model)),
                               np.asarray(y_flat), atol=2e-5, rtol=2e-5)


def test_seq_parallel_flag_numerically_identical():
    cfg = get_arch("qwen3-14b", smoke=True).replace(dtype="float32")
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    a, _ = T.forward(params, cfg, toks)
    b, _ = T.forward(params, cfg.replace(seq_parallel=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
