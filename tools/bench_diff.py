#!/usr/bin/env python
"""Compare fresh BENCH_*.json artifacts against committed baselines.

    python tools/bench_diff.py BASELINE_DIR CURRENT_DIR [--tol 0.05]
                               [--keys comm_bytes,comm_time_s,...]

Exit status: 0 when no monitored column regressed beyond the tolerance,
1 when at least one did, 2 on schema/usage errors — the CI gate behind
the committed perf trajectory (benchmarks/results/).

What counts: rows are matched by identity columns (dataset, algo, mode,
reducer, schedule, …); the monitored numeric columns (modeled comm bytes,
modeled seconds, round counts, modeled wall-clock) regress when
``current > baseline × (1 + tol)``. Artifacts whose ``meta.scale``
disagrees are skipped — a smoke run is never judged against a
full-protocol baseline. Improvements are listed so the baseline can be
re-committed, but never fail the gate.
"""
from __future__ import annotations

import argparse
import os
import sys

# runnable from a checkout without installing: python tools/bench_diff.py
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.obs.diff import DIFF_KEYS, BenchSchemaError, diff_dirs  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff BENCH_*.json artifacts against baselines "
                    "(nonzero exit on regression)")
    ap.add_argument("baseline_dir", help="committed baseline directory "
                                         "(e.g. benchmarks/results/smoke)")
    ap.add_argument("current_dir", help="fresh-run artifact directory "
                                        "(e.g. artifacts/bench)")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative regression tolerance (default 0.05 = 5%%)")
    ap.add_argument("--keys", default=",".join(DIFF_KEYS),
                    help="comma-separated monitored columns "
                         f"(default: {','.join(DIFF_KEYS)})")
    args = ap.parse_args(argv)

    keys = tuple(k for k in args.keys.split(",") if k)
    if not os.path.isdir(args.baseline_dir):
        print(f"bench_diff: baseline directory {args.baseline_dir!r} "
              "does not exist", file=sys.stderr)
        return 2
    try:
        dd = diff_dirs(args.baseline_dir, args.current_dir, keys=keys)
    except BenchSchemaError as e:
        print(f"bench_diff: {e}", file=sys.stderr)
        return 2

    for name in dd.compared:
        print(f"compared {name}")
    for reason in dd.skipped:
        print(f"skipped  {reason}")
    if not dd.compared:
        print("bench_diff: no artifacts compared (nothing to gate on)")
        return 0

    regs = dd.regressions(args.tol)
    imps = dd.improvements(args.tol)
    for d in imps:
        print(f"improved   {d.render()}")
    for d in regs:
        print(f"REGRESSED  {d.render()}")
    print(f"bench_diff: {len(dd.deltas)} cells compared, "
          f"{len(regs)} regression(s), {len(imps)} improvement(s) "
          f"at tol={args.tol:.0%}")
    return 1 if regs else 0


if __name__ == "__main__":
    raise SystemExit(main())
