#!/usr/bin/env python
"""Docs link checker — CI gate for docs/*.md (and README.md).

Verifies that every relative markdown link resolves to an existing file,
and that every anchor link (`#heading` or `file.md#heading`) points at a
heading that actually exists in the target file (GitHub slug rules:
lowercase, spaces -> dashes, punctuation dropped). External links
(http/https/mailto) are not fetched.

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    return re.sub(r"\s+", "-", h.strip())


def heading_slugs(path: Path) -> set:
    return {slugify(m.group(1))
            for m in HEADING_RE.finditer(path.read_text())}


def check_file(path: Path) -> list:
    errors = []
    for m in LINK_RE.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        dest = (path.parent / file_part).resolve() if file_part else path
        if not dest.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link {target!r}"
                          f" (no such file {file_part!r})")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in heading_slugs(dest):
                errors.append(
                    f"{path.relative_to(ROOT)}: broken anchor {target!r} "
                    f"(no heading slug {anchor!r} in "
                    f"{dest.relative_to(ROOT)})")
    return errors


def main() -> int:
    files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    missing = [f for f in files if not f.exists()]
    if missing:
        print(f"missing expected docs files: {missing}")
        return 1
    errors = []
    n_links = 0
    for f in files:
        n_links += len(LINK_RE.findall(f.read_text()))
        errors.extend(check_file(f))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} files, {n_links} links: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
